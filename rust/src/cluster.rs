//! Cluster composition: PEs + hierarchical interconnect + banked L1 +
//! fork-join barriers + the HBML DMA subsystem, advanced in lock-step one
//! cycle at a time (Sec. 4.2, Sec. 7).
//!
//! The fork-join SPMD model of the paper: after "boot" every PE runs its
//! trace concurrently; `Op::Barrier` arrivals are **real atomic
//! fetch&adds** on a Tile-local counter word (so the 8 PEs of a Tile
//! serialize at their bank, as in hardware), and the cross-Tile
//! aggregation + WFI wake-up broadcast is charged as the configurable
//! `barrier_wakeup` latency.
//!
//! Two execution engines share the same per-cycle semantics:
//!
//! * [`Cluster::run`] — the serial reference engine: one host thread
//!   steps every PE and every per-Tile memory domain in a fixed order
//!   each cycle.
//! * [`Cluster::run_parallel`] — the deterministic **fully sharded
//!   engine** (see DESIGN.md): response/wake delivery, barrier waiting
//!   lists, DMA waiters and the cross-shard transfer merge all live in
//!   the workers (owner-computes, per-(source, destination) mailboxes,
//!   a binary summary-reduction tree), each worker owning a contiguous
//!   Tile range (Tile → SubGroup → Group, the paper's physical
//!   hierarchy) — its PEs *and* its Tiles' memory domains and L1
//!   slices. The coordinator's per-cycle work is O(threads): global
//!   barrier counters, release scheduling, and the DMA
//!   channel-arbitration decisions (whose functional word movement is
//!   again partitioned across the workers by destination Tile).
//!   Results, cycle counts and statistics are bit-identical to the
//!   serial engine for any thread count
//!   (`rust/tests/parallel_equiv.rs`, 1–16 threads).

use std::collections::HashMap;

use crate::config::ClusterConfig;
use crate::dma::{DmaSubsystem, DmaWake};
use crate::interconnect::{Interconnect, ReqKind, Request, Response, Topology, XferEvent};
use crate::isa::{Program, MAX_BURST_WORDS};
use crate::memory::{AddressMap, L1Memory};
use crate::pe::{Action, Pe, PeState, PeStats};

/// Word offset inside each Tile's sequential region reserved for the
/// barrier arrival counter (kernel traces must not touch it).
pub const BARRIER_SLOT: u32 = 0;

#[derive(Debug, Default)]
struct BarrierSlot {
    arrived: u32,
    waiting: Vec<u32>,
    release_at: Option<u64>,
}

/// Aggregated run results (feeds Fig. 14a, Table 6, the headline numbers).
/// `PartialEq` backs the serial-vs-parallel differential tests: the two
/// engines must agree on every field, bit for bit.
#[derive(Debug, Clone, PartialEq)]
pub struct RunStats {
    pub cycles: u64,
    pub instructions: u64,
    pub flops: u64,
    pub num_pes: usize,
    pub freq_mhz: f64,
    pub stall_raw: u64,
    pub stall_lsu: u64,
    pub stall_ctrl: u64,
    pub stall_synch: u64,
    pub loads: u64,
    pub stores: u64,
    pub atomics: u64,
    /// Measured AMAT over all L1 requests (cycles).
    pub amat: f64,
    /// Measured AMAT per NUMA class.
    pub amat_per_class: [f64; 4],
    pub reqs_per_class: [u64; 4],
    /// Multi-word (burst) requests per NUMA class — a subset of
    /// `reqs_per_class`, so `reqs - burst_reqs` is the single-word
    /// traffic and a burst-off run reports all zeros here.
    pub burst_reqs_per_class: [u64; 4],
    /// Words moved by those burst requests.
    pub burst_words_per_class: [u64; 4],
}

impl RunStats {
    /// Instructions per cycle per PE (Fig. 14a's headline metric).
    pub fn ipc(&self) -> f64 {
        self.instructions as f64 / (self.cycles as f64 * self.num_pes as f64)
    }
    /// Fraction of PE-cycles in each category; sums to ≤ 1 (the remainder
    /// is post-halt idle of early-finishing PEs).
    pub fn fraction(&self, count: u64) -> f64 {
        count as f64 / (self.cycles as f64 * self.num_pes as f64)
    }
    /// Achieved GFLOP/s at the configured frequency.
    pub fn gflops(&self) -> f64 {
        self.flops as f64 / self.cycles as f64 * self.freq_mhz / 1000.0
    }
}

/// The simulated cluster.
pub struct Cluster {
    pub cfg: ClusterConfig,
    pub l1: L1Memory,
    pub icn: Interconnect,
    pub pes: Vec<Pe>,
    pub dma: Option<DmaSubsystem>,
    barriers: HashMap<u16, BarrierSlot>,
    dma_waiters: Vec<(u32, u16)>,
    pub cycle: u64,
    /// Event-driven idle-cycle skipping (on by default): when nothing
    /// can change until a scheduled event — every PE parked or halted,
    /// no request in flight, no DMA burst queued — both engines jump
    /// the cycle counter to the next wake event in O(parked PEs)
    /// instead of stepping the whole cluster once per empty cycle.
    /// Results are bit-identical either way (the differential suite
    /// runs the skip against the stepped engines); turn it off to
    /// benchmark the skip itself or to bisect a suspected skip bug.
    pub fast_forward: bool,
}

impl Cluster {
    /// Build a cluster with one program per PE (`programs.len()` must be
    /// `cfg.num_pes()`).
    pub fn new(cfg: ClusterConfig, programs: Vec<Program>) -> Self {
        assert_eq!(programs.len(), cfg.num_pes(), "one program per PE");
        let l1 = L1Memory::new(&cfg);
        let icn = Interconnect::new(&cfg);
        let ppt = cfg.hierarchy.pes_per_tile;
        let pes = programs
            .into_iter()
            .enumerate()
            .map(|(i, p)| Pe::new(i as u32, (i / ppt) as u32, cfg.tx_table_entries as u32, p))
            .collect();
        Cluster {
            cfg,
            l1,
            icn,
            pes,
            dma: None,
            barriers: HashMap::new(),
            dma_waiters: Vec::new(),
            cycle: 0,
            fast_forward: true,
        }
    }

    /// Attach the HBML (DMA + HBM2E) subsystem.
    pub fn with_dma(mut self) -> Self {
        self.dma = Some(DmaSubsystem::new(&self.cfg));
        self
    }

    /// Barrier-arrival bookkeeping for an acked atomic (serial engine;
    /// the per-PE part of a response lives in [`Pe::apply_response`]).
    /// The sharded engine splits the same bookkeeping in two halves that
    /// land on the same simulated cycles: arrival *counts* are tallied at
    /// drain time by the destination domain's worker (via the same
    /// [`Response::barrier_id`] classifier) and summed on the
    /// coordinator, while the *waiting list* is registered by the
    /// PE-owning worker when it applies the response.
    fn bookkeep_barrier(barriers: &mut HashMap<u16, BarrierSlot>, r: &Response) {
        if let Some(id) = r.barrier_id() {
            // Barrier arrival atomic acked → count it.
            let slot = barriers.entry(id).or_default();
            slot.arrived += 1;
            slot.waiting.push(r.core);
        }
    }

    /// Barrier release check (step 2 of the cycle): all arrived →
    /// broadcast wake after the aggregation/WFI latency. Shared by both
    /// engines: `release` receives the releasing barrier id and its
    /// waiting list — the serial engine wakes the listed PEs directly,
    /// the sharded coordinator broadcasts the id through the control
    /// block (its waiting lists live with the PE-owning workers, so the
    /// list here is empty).
    fn release_barriers(
        barriers: &mut HashMap<u16, BarrierSlot>,
        now: u64,
        expected: u32,
        wakeup: u64,
        mut release: impl FnMut(u16, &[u32]),
    ) {
        for (&id, slot) in barriers.iter_mut() {
            if slot.arrived == expected && slot.release_at.is_none() {
                slot.release_at = Some(now + wakeup);
            }
            if slot.release_at == Some(now) {
                release(id, &slot.waiting);
                slot.waiting.clear();
                slot.arrived = 0;
                slot.release_at = None;
            }
        }
    }

    /// DMA/HBM progress + DmaWait-parked wake-ups (step 3 of the cycle)
    /// — the serial engine's inline form. The sharded engine runs the
    /// same timing core ([`crate::dma::DmaSubsystem::step_events`]) on
    /// its coordinator but partitions the functional word movement
    /// across the workers by destination Tile and shards the waiter
    /// lists per worker (woken the same cycle via the control block's
    /// retirement broadcast). The L1 goes in by shared reference: the
    /// word movement uses the per-Tile slice locks, which are free here
    /// (no memory domain is being stepped during DMA progress).
    fn dma_progress(
        dma: &mut Option<DmaSubsystem>,
        dma_waiters: &mut Vec<(u32, u16)>,
        now: u64,
        l1: &L1Memory,
        mut wake: impl FnMut(u32),
    ) {
        if let Some(d) = dma.as_mut() {
            d.step(now, l1);
            dma_waiters.retain(|&(pe, id)| {
                if d.is_done(id) {
                    wake(pe);
                    false
                } else {
                    true
                }
            });
        }
    }

    /// Route one DMA control op (serial engine): `DmaStart` programs the
    /// frontend stamped with the op's issue cycle; `DmaWait` wakes the PE
    /// in-cycle when the descriptor already retired or parks it among the
    /// waiters otherwise. The sharded engine mirrors both halves exactly:
    /// workers resolve `DmaWait` against their descriptor done-mirrors
    /// (same state, same point in the cycle) and park waiters locally,
    /// while `DmaStart` travels up the summary tree and is applied by the
    /// coordinator with the same issue-cycle stamp.
    fn dma_control(
        dma: &mut Option<DmaSubsystem>,
        dma_waiters: &mut Vec<(u32, u16)>,
        issued_at: u64,
        pe: u32,
        action: Action,
        mut wake: impl FnMut(u32),
    ) {
        match action {
            Action::DmaStart { id } => dma
                .as_mut()
                .expect("trace uses DMA but cluster built without with_dma()")
                .start(id, issued_at),
            Action::DmaWait { id } => {
                let done = dma.as_ref().map(|d| d.is_done(id)).unwrap_or(true);
                if done {
                    // DmaWait on an already-retired descriptor: resume
                    // next cycle (the issue slot is spent either way).
                    wake(pe);
                } else {
                    dma_waiters.push((pe, id));
                }
            }
            _ => unreachable!("only DMA control ops reach dma_control"),
        }
    }

    /// Advance a single cycle.
    pub fn step(&mut self) {
        let now = self.cycle;

        // 1. Deliver L1 responses due this cycle (drained from the domain
        //    wheels at the end of the previous cycle's memory step).
        let pes = &mut self.pes;
        let barriers = &mut self.barriers;
        self.icn.drain_responses(now, |r| {
            pes[r.core as usize].apply_response(&r);
            Self::bookkeep_barrier(barriers, &r);
        });

        // 2. Barrier release.
        let expected = self.pes.len() as u32;
        let pes = &mut self.pes;
        Self::release_barriers(
            &mut self.barriers,
            now,
            expected,
            self.cfg.barrier_wakeup as u64,
            |_id, waiting| {
                for &pe in waiting {
                    pes[pe as usize].wake();
                }
            },
        );

        // 3. DMA / HBM progress; wake DmaWait-parked PEs.
        let pes = &mut self.pes;
        Self::dma_progress(&mut self.dma, &mut self.dma_waiters, now, &self.l1, |pe| {
            pes[pe as usize].wake()
        });

        // 4. PE issue phase: bucket every action by the pure routing
        //    function shared with the parallel workers, then ingest.
        let ppt = self.cfg.hierarchy.pes_per_tile;
        for i in 0..self.pes.len() {
            let action = self.pes[i].try_issue();
            if action == Action::None {
                continue;
            }
            let tile = i / ppt;
            let routed =
                route_action(now, i as u32, tile, action, &self.l1.map, self.icn.topo());
            match routed {
                RoutedAction::None => {}
                RoutedAction::Mem { reqs } => {
                    for (req, master_port) in reqs.into_iter().flatten() {
                        self.icn.ingest(tile, req, master_port);
                    }
                }
                RoutedAction::Dma(op) => {
                    let pes = &mut self.pes;
                    Self::dma_control(
                        &mut self.dma,
                        &mut self.dma_waiters,
                        now,
                        i as u32,
                        op,
                        |pe| pes[pe as usize].wake(),
                    );
                }
            }
        }

        // 5. Memory step: cross-shard transfer merge, then per-Tile
        //    master/slave/bank arbitration and bank accesses.
        self.icn.step(now, &mut self.l1);

        self.cycle += 1;
    }

    /// All PEs halted, no requests in flight, DMA drained.
    pub fn done(&self) -> bool {
        self.pes.iter().all(|p| p.done())
            && self.icn.inflight() == 0
            && self.dma.as_ref().map(|d| d.idle()).unwrap_or(true)
    }

    /// Earliest *scheduled* event a quiescent cluster can wake on — a
    /// barrier release or an HBM burst completion — or `None` when
    /// something rules the skip out: a fully-arrived barrier whose
    /// release is not scheduled yet (the next step schedules it), a
    /// queued DMA burst (per-cycle arbitration), or an event due this
    /// very cycle. `limit` doubles as the deadlock target: a quiescent
    /// cluster with no event scheduled at all can only run out its
    /// cycle budget, and jumping straight there is exactly what
    /// stepping the empty cycles one by one would do.
    ///
    /// Shared by both engines: the serial skip wraps it with the PE /
    /// interconnect quiescence checks, the sharded coordinator feeds it
    /// the same barrier map and DMA subsystem it already owns.
    fn next_wake_cycle(
        barriers: &HashMap<u16, BarrierSlot>,
        dma: &Option<DmaSubsystem>,
        expected: u32,
        now: u64,
        limit: u64,
    ) -> Option<u64> {
        let mut wake = limit;
        for slot in barriers.values() {
            if slot.arrived == expected && slot.release_at.is_none() {
                return None;
            }
            if let Some(at) = slot.release_at {
                if at <= now {
                    return None;
                }
                wake = wake.min(at);
            }
        }
        if let Some(d) = dma.as_ref() {
            match d.next_wake() {
                DmaWake::Busy => return None,
                DmaWake::At(at) => {
                    if at <= now {
                        return None;
                    }
                    wake = wake.min(at);
                }
                DmaWake::Idle => {}
            }
        }
        (wake > now).then_some(wake)
    }

    /// Serial-engine skip decision: `Some(wake)` when the cluster is
    /// quiescent — no PE runnable, nothing in flight or pending in the
    /// memory system, no DMA burst queued, no waiter owed a wake — and
    /// the next scheduled event (clamped to `max_cycles`) lies strictly
    /// ahead. During such a span every [`Cluster::step`] is a no-op
    /// except for the parked PEs' per-cycle `Synch` stall charge, which
    /// [`Cluster::skip_idle_span`] credits in one update.
    fn idle_skip_target(&self, max_cycles: u64) -> Option<u64> {
        if self.pes.iter().any(|p| p.state == PeState::Running) {
            return None;
        }
        if self.icn.inflight() != 0 || self.icn.has_pending() {
            return None;
        }
        if let Some(d) = self.dma.as_ref() {
            // A waiter whose descriptor already retired is woken by the
            // next step's DMA-progress sweep — that step must run.
            if self.dma_waiters.iter().any(|&(_, id)| d.is_done(id)) {
                return None;
            }
        }
        let expected = self.pes.len() as u32;
        Self::next_wake_cycle(&self.barriers, &self.dma, expected, self.cycle, max_cycles)
    }

    /// Jump the serial engine to `wake`, crediting each parked PE with
    /// the skipped span's synch stalls — the only state a quiescent
    /// span mutates.
    fn skip_idle_span(&mut self, wake: u64) {
        let span = wake - self.cycle;
        for pe in self.pes.iter_mut() {
            if matches!(pe.state, PeState::AtBarrier | PeState::WaitDma) {
                pe.note_idle_span(span);
            }
        }
        self.cycle = wake;
    }

    /// Run to completion (or `max_cycles`); returns aggregated stats.
    /// Panics on a timeout — harness entry points that must not compare a
    /// half-finished memory image use [`Cluster::try_run_threads`], which
    /// surfaces the same condition as a typed
    /// [`crate::errors::ErrorKind::MaxCyclesExceeded`] instead.
    pub fn run(&mut self, max_cycles: u64) -> RunStats {
        self.try_run(max_cycles).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`Cluster::run`] without the panic: `Err(MaxCyclesExceeded)` when
    /// the cluster is not [`Cluster::done`] after `max_cycles`.
    pub fn try_run(&mut self, max_cycles: u64) -> crate::errors::Result<RunStats> {
        while !self.done() && self.cycle < max_cycles {
            if self.fast_forward {
                if let Some(wake) = self.idle_skip_target(max_cycles) {
                    self.skip_idle_span(wake);
                    continue;
                }
            }
            self.step();
        }
        if !self.done() {
            return Err(crate::errors::Error::max_cycles("cluster", max_cycles));
        }
        Ok(self.stats())
    }

    /// Engine dispatch: `threads <= 1` runs the serial reference engine,
    /// anything larger the tile-parallel engine. The single place the
    /// CLI/coordinator/benches branch between the two.
    pub fn run_threads(&mut self, max_cycles: u64, threads: usize) -> RunStats {
        if threads > 1 {
            self.run_parallel(max_cycles, threads)
        } else {
            self.run(max_cycles)
        }
    }

    /// [`Cluster::run_threads`] with the timeout surfaced as a typed
    /// error instead of a panic — the `Session` run path, which must
    /// never read output from (or report stats of) an unfinished run.
    pub fn try_run_threads(
        &mut self,
        max_cycles: u64,
        threads: usize,
    ) -> crate::errors::Result<RunStats> {
        if threads > 1 {
            self.try_run_parallel(max_cycles, threads)
        } else {
            self.try_run(max_cycles)
        }
    }

    /// Run to completion on the deterministic fully sharded engine with
    /// `threads` host worker threads (clamped to `[1, num_tiles]`).
    /// Cycle counts, memory image and statistics are bit-identical to
    /// [`Cluster::run`] for every thread count; see the module docs and
    /// DESIGN.md for the determinism argument. Panics on a timeout, like
    /// [`Cluster::run`]; `Session` uses [`Cluster::try_run_threads`].
    pub fn run_parallel(&mut self, max_cycles: u64, threads: usize) -> RunStats {
        self.try_run_parallel(max_cycles, threads)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    fn try_run_parallel(
        &mut self,
        max_cycles: u64,
        threads: usize,
    ) -> crate::errors::Result<RunStats> {
        use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
        use std::sync::{Mutex, RwLock};

        use crate::dma::{hbm_image_read, hbm_image_write, DmaEvent};
        use crate::parallel::{
            await_summary, worker_loop, ControlBlock, CycleSummary, DmaJob, PoolShutdown,
            SpinBarrier, WorkerChannel, WorkerCtx,
        };

        let num_tiles = self.cfg.num_tiles();
        let ppt = self.cfg.hierarchy.pes_per_tile;
        let workers = threads.clamp(1, num_tiles);
        // Contiguous Tile ranges per worker: a worker owns a Tile's PEs
        // *and* its memory domain + L1 slice, so phase-1 buckets never
        // cross workers, and draining per-(source, destination) mailboxes
        // in ascending source order reproduces the serial engine's
        // Tile-ascending order.
        let tiles_per_worker = num_tiles.div_ceil(workers);
        let pes_per_worker = tiles_per_worker * ppt;
        let expected = self.pes.len() as u32;
        let wakeup = self.cfg.barrier_wakeup as u64;
        let has_dma = self.dma.is_some();
        let fast_forward = self.fast_forward;

        let channels: Vec<WorkerChannel> = (0..workers)
            .map(|w| WorkerChannel::new((w * pes_per_worker) as u32, workers))
            .collect();
        let barrier = SpinBarrier::new(workers + 1);
        let stop = AtomicBool::new(false);
        let failed = AtomicBool::new(false);
        let now_shared = AtomicU64::new(self.cycle);

        // Split the cluster into disjoint field borrows: the PE array is
        // handed to the workers for the whole run; the memory system is
        // shared (workers lock their own Tiles during their phase, the
        // coordinator never touches it); the DMA timing model and the
        // global barrier counters stay with the coordinator (this
        // thread), everything else about barriers/DMA is sharded.
        let Cluster {
            cfg: _,
            l1,
            icn,
            pes,
            dma,
            barriers,
            dma_waiters,
            cycle,
            fast_forward: _,
        } = self;

        let init_busy = pes.iter().any(|p| !p.done());
        let init_runnable = pes.iter().any(|p| p.state == PeState::Running);

        // Carry-over from earlier serial stepping on the same cluster:
        // requests alive in the memory system, already-drained responses,
        // unmerged transfer events, parked PEs and retired descriptors —
        // all seeded into the first cycle's control block for the owning
        // workers to pick up.
        let carry_inflight = icn.inflight() as i64;
        let mut seed_events = 0u64;
        let mut cb0 = ControlBlock {
            seed_resp: (0..workers).map(|_| Mutex::new(Vec::new())).collect(),
            seed_xfer: (0..workers).map(|_| Mutex::new(Vec::new())).collect(),
            ..ControlBlock::default()
        };
        // Scratch pair for the pending-event hand-off, reused as the
        // post-scope restore buffers below: the interconnect's own
        // carry-over queues keep their capacity (drain_pending appends
        // and leaves them empty) and no per-run Vecs are thrown away.
        let mut pend_resp: Vec<Response> = Vec::new();
        let mut pend_xfer: Vec<XferEvent> = Vec::new();
        icn.drain_pending(&mut pend_resp, &mut pend_xfer);
        for r in pend_resp.drain(..) {
            // Arrival counts land here (the cycle the response is
            // delivered — exactly when the serial engine would bookkeep
            // it); the waiting-list half is registered by the owning
            // worker when it applies the seeded response.
            if let Some(id) = r.barrier_id() {
                barriers.entry(id).or_default().arrived += 1;
            }
            seed_events += 1;
            cb0.seed_resp[r.core as usize / pes_per_worker]
                .get_mut()
                .unwrap()
                .push(r);
        }
        for ev in pend_xfer.drain(..) {
            seed_events += 1;
            cb0.seed_xfer[ev.dst_tile as usize / tiles_per_worker]
                .get_mut()
                .unwrap()
                .push(ev);
        }
        for (&id, slot) in barriers.iter_mut() {
            for pe in slot.waiting.drain(..) {
                cb0.seed_waiting.push((id, pe));
            }
        }
        cb0.seed_dma_waiters = std::mem::take(dma_waiters);
        if let Some(d) = dma.as_ref() {
            // Descriptors already retired seed the workers' done-mirrors.
            cb0.dma_done = d.done_ids();
        }
        let ctrl = RwLock::new(cb0);

        let l1_ref: &L1Memory = l1;
        let icn_ref: &Interconnect = icn;

        std::thread::scope(|s| {
            let mut rest: &mut [Pe] = pes;
            for w in 0..workers {
                let take = pes_per_worker.min(rest.len());
                // mem::take detaches the slice from `rest` so the chunk
                // borrows 'scope-long, not loop-iteration-long.
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(take);
                rest = tail;
                let ctx = WorkerCtx {
                    idx: w,
                    channels: &channels,
                    ctrl: &ctrl,
                    icn: icn_ref,
                    l1: l1_ref,
                    tile_lo: (w * tiles_per_worker).min(num_tiles),
                    tile_hi: ((w + 1) * tiles_per_worker).min(num_tiles),
                    pes_per_tile: ppt,
                    tiles_per_worker,
                    pes_per_worker,
                    has_dma,
                    now: &now_shared,
                };
                let barrier = &barrier;
                let stop = &stop;
                let failed = &failed;
                s.spawn(move || worker_loop(chunk, ctx, barrier, stop, failed));
            }
            // Releases the pool exactly once when the coordinator leaves
            // this closure — by `break` or by unwinding from a panic.
            let _shutdown = PoolShutdown::new(&stop, &barrier);

            // Root of the summary tree; the first check runs on the
            // pre-spawn state (workers have produced nothing yet).
            let mut root = CycleSummary {
                busy: init_busy,
                runnable: init_runnable,
                events: seed_events,
                ..CycleSummary::default()
            };
            let mut first = true;
            let mut seeds_cleared = false;
            // Recycled staging buffer for outbound burst words.
            let mut out_words: Vec<f32> = Vec::new();
            // Recycled inbound-job buffers: the workers only read the
            // jobs during their cycle top, so by the time the
            // coordinator holds the write lock again the data Vecs are
            // dead capacity — harvest them instead of reallocating one
            // per burst per cycle.
            let mut job_pool: Vec<Vec<f32>> = Vec::new();

            loop {
                let now = *cycle;

                // --- serial pre-phase: O(threads) + DMA decisions -----
                // (a) Collect the tree-merged cycle summary (a single
                // root swap — the workers did the merging).
                if !first {
                    let mut slot = channels[0].summary.lock().unwrap();
                    std::mem::swap(&mut *slot, &mut root);
                }

                // (b) DmaStart ops issued during the previous cycle, in
                // global PE order (the summary tree concatenated them in
                // worker order). `start` is stamped with the issue cycle,
                // so frontend occupancy chains exactly as in the serial
                // engine — which also programmed the frontend *during*
                // cycle `now - 1`, which is why this happens before the
                // termination check: a timeout must leave the frontend in
                // the serial engine's state. DmaWait never crosses to the
                // coordinator — the workers resolve it against their
                // done-mirrors.
                let issued_at = now.saturating_sub(1);
                for (_pe, op) in root.dma_ops.drain(..) {
                    match op {
                        Action::DmaStart { id } => dma
                            .as_mut()
                            .expect("trace uses DMA but cluster built without with_dma()")
                            .start(id, issued_at),
                        _ => unreachable!("only DmaStart crosses to the coordinator"),
                    }
                }

                // (c) Termination — decided *before* the rest of the
                // pre-phase mutates anything, exactly like the serial
                // loop's `while !done() && cycle < max` guard. On a
                // timeout, the summary's unconsumed arrival tallies
                // belong to the never-executed cycle `now` and are
                // dropped — their responses sit undelivered in the
                // mailboxes and are restored to the interconnect's
                // pending queues after the scope, just as the serial
                // engine would still hold them for redelivery. On the
                // `done` path nothing is dropped: drained arrivals imply
                // `events > 0`.
                let inflight: i64 = carry_inflight
                    + channels
                        .iter()
                        .map(|c| c.inflight.load(Ordering::SeqCst))
                        .sum::<i64>();
                let done = !root.busy
                    && inflight == 0
                    && root.events == 0
                    && dma.as_ref().map(|d| d.idle()).unwrap_or(true);
                if done || now >= max_cycles {
                    break; // _shutdown releases the workers
                }

                // (d) Barrier arrivals the workers counted at drain time
                // last cycle — delivered to the PEs this cycle, so the
                // global counters advance exactly when the serial
                // engine's bookkeeping would.
                for (id, n) in root.arrivals.iter() {
                    barriers.entry(id).or_default().arrived += n;
                }
                root.arrivals.clear();

                // (d2) Idle-cycle fast-forward: with no PE runnable after
                // the last phase 1, nothing in flight or published, and
                // no DMA burst queued, the cluster is quiescent — every
                // cycle until the next scheduled event (barrier release /
                // HBM completion) would only re-charge the parked PEs'
                // synch stalls. Jump `now` there; the workers credit the
                // skipped span via the control block's `skip` field at
                // their next cycle top, then the wake cycle itself runs
                // normally (its release/retirement publishes below use
                // the advanced `now`). The first iteration never skips:
                // its cycle top consumes the mixed-engine seeds (e.g. a
                // DmaWait parked on an already-retired descriptor must
                // wake *this* cycle, as the serial engine would).
                // Clamped to `max_cycles - 1` so the final budgeted
                // cycle executes normally — its per-parked-PE stall and
                // the `cycle` advance to `max_cycles` land exactly as in
                // the serial engine's timeout path.
                let mut skip = 0u64;
                if fast_forward && !first && !root.runnable && inflight == 0 && root.events == 0
                {
                    let limit = max_cycles.saturating_sub(1);
                    if let Some(wake) =
                        Self::next_wake_cycle(barriers, dma, expected, now, limit)
                    {
                        skip = wake - now;
                    }
                }
                let now = now + skip;
                if skip > 0 {
                    *cycle = now;
                }

                // (e) Publish this cycle's control block: barrier
                // releases, DMA retirements and inbound data-movement
                // jobs.
                let mut cbw = ctrl.write().unwrap();
                let cb = &mut *cbw;
                if !first {
                    // Last cycle's retirement broadcast was consumed at
                    // the workers' cycle top (first cycle: the broadcast
                    // carries the pre-retired-descriptor seed instead).
                    cb.dma_done.clear();
                }
                for job in cb.dma_jobs.drain(..) {
                    let mut buf = job.data;
                    buf.clear();
                    job_pool.push(buf);
                }
                cb.releases.clear();
                cb.skip = skip;
                if let Some(d) = dma.as_mut() {
                    // DMA timing step: channel arbitration and burst
                    // issue stay serial. Inbound bursts become jobs whose
                    // L1-side writes the workers partition across their
                    // Tile ranges this cycle (same cycle the serial
                    // engine moves the words). Outbound bursts move
                    // inline right here — L1 reads (slice locks are free:
                    // the workers are parked) and image writes at the
                    // exact serial point in burst order, so the image is
                    // bit-identical even when an inbound burst reads
                    // bytes an outbound burst wrote the same cycle.
                    d.step_events(now, |ev| match ev {
                        DmaEvent::Issue { l1_word, words, mem_byte, to_l1 } => {
                            if to_l1 {
                                let mut data = job_pool.pop().unwrap_or_default();
                                data.reserve(words as usize);
                                data.extend(
                                    (0..words)
                                        .map(|w| hbm_image_read(mem_byte + w as u64 * 4)),
                                );
                                cb.dma_jobs.push(DmaJob { l1_word, data });
                            } else {
                                // The serial engine moves every burst at
                                // its event, in burst order — so an
                                // inbound burst issued *earlier this
                                // cycle* whose L1 run overlaps must land
                                // before this read. Flushing the job here
                                // is idempotent with the workers'
                                // cycle-top re-apply (same words, and
                                // nothing reads L1 in between).
                                let (b0, b1) =
                                    (l1_word as u64, l1_word as u64 + words as u64);
                                for job in cb.dma_jobs.iter() {
                                    let a0 = job.l1_word as u64;
                                    let a1 = a0 + job.data.len() as u64;
                                    if a0 < b1 && b0 < a1 {
                                        l1_ref.write_run_shared(job.l1_word, &job.data);
                                    }
                                }
                                l1_ref.read_run_shared(l1_word, words as usize, &mut out_words);
                                for (w, &v) in out_words.iter().enumerate() {
                                    hbm_image_write(mem_byte + w as u64 * 4, v);
                                }
                            }
                        }
                        DmaEvent::Retired { id } => cb.dma_done.push(id),
                    });
                }
                Self::release_barriers(barriers, now, expected, wakeup, |id, _waiting| {
                    cb.releases.push(id);
                });
                first = false;
                drop(cbw);

                // --- the sharded cycle: cycle-top delivery + phase 1 +
                // phase 2 + summary reduction, all inside the workers ---
                now_shared.store(now, Ordering::SeqCst);
                barrier.wait();
                // Fused completion wait: instead of a second barrier
                // crossing, observe the summary tree's root ready-stamp.
                // Every worker's stamp is transitively awaited along the
                // root's subtree chain (Release/Acquire), so once this
                // returns, all workers have published their mailboxes,
                // updated `inflight`, dropped their ctrl read guards and
                // are on their way back to the cycle-top rendezvous —
                // the pre-phase above can mutate freely.
                await_summary(&channels[0].summary_ready, now, &failed);
                if failed.load(Ordering::SeqCst) {
                    // _shutdown drains the pool during the unwind.
                    panic!("parallel engine: a worker thread panicked");
                }
                *cycle += 1;

                // The parked-PE seeds were *copied* (not drained) by
                // their owning workers during the phase that just
                // completed: clear them now — not in a later pre-phase,
                // which a termination break could skip, leaving the
                // post-scope restore to double-count waiters the workers
                // already own (and re-add ones already woken).
                if !seeds_cleared {
                    seeds_cleared = true;
                    let mut cbw = ctrl.write().unwrap();
                    cbw.seed_waiting.clear();
                    cbw.seed_dma_waiters.clear();
                }
            }
        });

        // Collect the workers' parked state back into the cluster so
        // mixed-engine continuation (or error reporting) sees consistent
        // barrier/DMA bookkeeping.
        for ch in &channels {
            let mut parked = ch.parked.lock().unwrap();
            for (id, pe) in parked.barrier_waiting.drain(..) {
                barriers.entry(id).or_default().waiting.push(pe);
            }
            dma_waiters.append(&mut parked.dma_waiters);
        }
        // Undelivered events and unconsumed seeds survive only a timeout
        // exit (on the `done` path everything was consumed: parked PEs
        // imply `busy`, published events imply `events > 0`). Restore
        // them — parked-PE halves into the barrier/DMA bookkeeping,
        // response/transfer streams into the interconnect's pending
        // queues — so continuation redelivers them exactly as the serial
        // engine, which still holds such events at its own timeout,
        // would. Per-(source, destination) stream order is preserved,
        // the only order redelivery observes.
        let cb_rest = ctrl.into_inner().unwrap();
        for (id, pe) in cb_rest.seed_waiting {
            barriers.entry(id).or_default().waiting.push(pe);
        }
        dma_waiters.extend(cb_rest.seed_dma_waiters);
        // Recycle the seed scratch (emptied above) as the restore
        // buffers instead of allocating a fresh pair per run.
        let mut rest_resp = pend_resp;
        let mut rest_xfer = pend_xfer;
        for cell in &cb_rest.seed_resp {
            rest_resp.append(&mut cell.lock().unwrap());
        }
        for cell in &cb_rest.seed_xfer {
            rest_xfer.append(&mut cell.lock().unwrap());
        }
        for parity in 0..2 {
            for dst in 0..workers {
                for src in &channels {
                    src.resp_to(parity, dst).consume(|r| rest_resp.push(r));
                    src.xfer_to(parity, dst).consume(|ev| rest_xfer.push(ev));
                }
            }
        }
        if !rest_resp.is_empty() || !rest_xfer.is_empty() {
            icn.restore_pending(rest_resp, rest_xfer);
        }

        let inflight: i64 = carry_inflight
            + channels
                .iter()
                .map(|c| c.inflight.load(std::sync::atomic::Ordering::SeqCst))
                .sum::<i64>();
        // Individual worker counters may sit below zero (a request can be
        // born in one worker's source Tile and retire in another's
        // destination Tile), but the total is a population count and must
        // never be negative — that would mean double-counted deaths.
        debug_assert!(inflight >= 0, "negative in-flight total {inflight}");
        self.icn.set_inflight(inflight.max(0) as u64);
        if !self.done() {
            return Err(crate::errors::Error::max_cycles("cluster", max_cycles));
        }
        Ok(self.stats())
    }

    /// Aggregate statistics at the current cycle.
    pub fn stats(&self) -> RunStats {
        let mut agg = PeStats::default();
        for pe in &self.pes {
            let s = &pe.stats;
            agg.issued += s.issued;
            agg.flops += s.flops;
            agg.loads += s.loads;
            agg.stores += s.stores;
            agg.atomics += s.atomics;
            agg.stall_raw += s.stall_raw;
            agg.stall_lsu += s.stall_lsu;
            agg.stall_ctrl += s.stall_ctrl;
            agg.stall_synch += s.stall_synch;
        }
        let ic = self.icn.stats();
        RunStats {
            cycles: self.cycle,
            instructions: agg.issued,
            flops: agg.flops,
            num_pes: self.pes.len(),
            freq_mhz: self.cfg.freq_mhz,
            stall_raw: agg.stall_raw,
            stall_lsu: agg.stall_lsu,
            stall_ctrl: agg.stall_ctrl,
            stall_synch: agg.stall_synch,
            loads: agg.loads,
            stores: agg.stores,
            atomics: agg.atomics,
            amat: ic.amat(),
            amat_per_class: [
                ic.per_class[0].amat(),
                ic.per_class[1].amat(),
                ic.per_class[2].amat(),
                ic.per_class[3].amat(),
            ],
            reqs_per_class: [
                ic.per_class[0].count,
                ic.per_class[1].count,
                ic.per_class[2].count,
                ic.per_class[3].count,
            ],
            burst_reqs_per_class: [
                ic.per_class[0].burst_count,
                ic.per_class[1].burst_count,
                ic.per_class[2].burst_count,
                ic.per_class[3].burst_count,
            ],
            burst_words_per_class: [
                ic.per_class[0].burst_words,
                ic.per_class[1].burst_words,
                ic.per_class[2].burst_words,
                ic.per_class[3].burst_words,
            ],
        }
    }

    /// Convenience: the NUMA class histogram as fractions.
    pub fn class_mix(&self) -> [f64; 4] {
        let stats = self.icn.stats();
        let total: u64 = stats.per_class.iter().map(|c| c.count).sum();
        let mut out = [0.0; 4];
        if total > 0 {
            for (i, c) in stats.per_class.iter().enumerate() {
                out[i] = c.count as f64 / total as f64;
            }
        }
        out
    }
}

/// One PE action resolved against the shared routing function.
pub(crate) enum RoutedAction {
    None,
    /// Memory request(s) for the issuing Tile's domain (see
    /// [`Topology::make_request`] for the per-slot `master_port`
    /// contract). A single-word action fills slot 0; a burst fills one
    /// slot per consecutive-bank run ([`AddressMap::map_burst`]), in
    /// ascending-address order. A fixed array keeps the issue path
    /// allocation-free; consume with `.into_iter().flatten()`.
    Mem { reqs: [Option<(Request, Option<u8>)>; MAX_BURST_WORDS] },
    /// DMA control (`Action::DmaStart`/`DmaWait`): the serial issue loop
    /// routes both through [`Cluster::dma_control`] directly; the sharded
    /// engine's workers resolve `DmaWait` locally against their
    /// descriptor done-mirrors (bit-identical timing) and send `DmaStart`
    /// up the summary tree to the coordinator, which applies it with the
    /// same issue-cycle stamp.
    Dma(Action),
}

/// Route one PE action: a **pure function** of the address map and the
/// topology, shared verbatim by the serial issue loop and the parallel
/// engine's phase-1 workers, so both engines build identical requests and
/// bucket them identically. Barrier arrivals become real atomics on the
/// Tile-local counter word.
pub(crate) fn route_action(
    now: u64,
    pe: u32,
    tile: usize,
    action: Action,
    map: &AddressMap,
    topo: &Topology,
) -> RoutedAction {
    // Slot 0 of the fixed request array (single-word actions).
    let one = |req: Request, master_port: Option<u8>| {
        let mut reqs = [None; MAX_BURST_WORDS];
        reqs[0] = Some((req, master_port));
        RoutedAction::Mem { reqs }
    };
    match action {
        Action::None => RoutedAction::None,
        Action::Load { rd, addr } => {
            let bank = map.map(addr);
            let (req, master_port) =
                topo.make_request(now, pe, tile, ReqKind::Read { rd }, 0.0, bank, 0);
            one(req, master_port)
        }
        Action::Store { value, addr } => {
            let bank = map.map(addr);
            let (req, master_port) =
                topo.make_request(now, pe, tile, ReqKind::Write, value, bank, 0);
            one(req, master_port)
        }
        Action::LoadBurst { rd, addr, n } => {
            let mut reqs = [None; MAX_BURST_WORDS];
            let (mut idx, mut off) = (0usize, 0u8);
            map.map_burst(addr, n, |bank, len| {
                // Run k targets registers rd+off.. — the split carries
                // the register window with the addresses.
                let (mut req, port) =
                    topo.make_request(now, pe, tile, ReqKind::Read { rd: rd + off }, 0.0, bank, 0);
                req.words = len;
                req.last = false;
                reqs[idx] = Some((req, port));
                idx += 1;
                off += len;
            });
            if let Some((req, _)) = reqs[idx - 1].as_mut() {
                req.last = true; // final run releases the tx-table entry
            }
            RoutedAction::Mem { reqs }
        }
        Action::StoreBurst { addr, n, values } => {
            let mut reqs = [None; MAX_BURST_WORDS];
            let (mut idx, mut off) = (0usize, 0u8);
            map.map_burst(addr, n, |bank, len| {
                let (mut req, port) = topo.make_request(
                    now,
                    pe,
                    tile,
                    ReqKind::Write,
                    values[off as usize],
                    bank,
                    0,
                );
                req.words = len;
                req.last = false;
                for k in 0..len as usize {
                    req.wdata[k] = values[off as usize + k];
                }
                reqs[idx] = Some((req, port));
                idx += 1;
                off += len;
            });
            if let Some((req, _)) = reqs[idx - 1].as_mut() {
                req.last = true;
            }
            RoutedAction::Mem { reqs }
        }
        Action::AmoAdd { value, addr } => {
            let bank = map.map(addr);
            let (req, master_port) =
                topo.make_request(now, pe, tile, ReqKind::Amo, value, bank, 0);
            one(req, master_port)
        }
        Action::BarrierArrive { id } => {
            // Barrier-counter word: sequential-region slot 0 of the Tile.
            let addr = map.seq_base_of_tile(tile) + BARRIER_SLOT;
            let bank = map.map(addr);
            let (req, master_port) =
                topo.make_request(now, pe, tile, ReqKind::Amo, 1.0, bank, id as u32 + 1);
            one(req, master_port)
        }
        Action::DmaStart { .. } | Action::DmaWait { .. } => RoutedAction::Dma(action),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Op;

    fn programs_for(cfg: &ClusterConfig, f: impl Fn(usize) -> Program) -> Vec<Program> {
        (0..cfg.num_pes()).map(f).collect()
    }

    #[test]
    fn every_pe_executes_and_halts() {
        let cfg = ClusterConfig::tiny();
        let progs = programs_for(&cfg, |i| {
            let mut p = Program::new();
            p.ld_imm(1, i as f32);
            p.ld_imm(2, 2.0);
            p.mul(3, 1, 2);
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs);
        let stats = cl.run(1000);
        assert_eq!(stats.instructions, 32 * 3);
        for (i, pe) in cl.pes.iter().enumerate() {
            assert_eq!(pe.reg(3), i as f32 * 2.0);
        }
    }

    #[test]
    fn store_then_load_roundtrip_through_l1() {
        let cfg = ClusterConfig::tiny();
        let base = L1Memory::new(&cfg).map.interleaved_base();
        let out = base + 256; // separate output region (no write race)
        let progs = programs_for(&cfg, |i| {
            let mut p = Program::new();
            p.ld_imm(1, 100.0 + i as f32);
            p.st(1, base + i as u32);
            p.barrier(0);
            // read the neighbour's word (wraps) and store to the output
            let n = base + ((i as u32 + 1) % 32);
            p.ld(2, n);
            p.st(2, out + i as u32);
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs);
        cl.run(10_000);
        for i in 0..32u32 {
            let got = cl.l1.read(out + i);
            assert_eq!(got, 100.0 + ((i + 1) % 32) as f32, "word {i}");
        }
    }

    #[test]
    fn barrier_synchronizes_all_pes() {
        let cfg = ClusterConfig::tiny();
        // PE 0 does a long prologue; all others wait at the barrier. After
        // the barrier each PE loads the word PE 0 wrote.
        let base = L1Memory::new(&cfg).map.interleaved_base();
        let flag = base + 500;
        let progs = programs_for(&cfg, |i| {
            let mut p = Program::new();
            if i == 0 {
                for _ in 0..200 {
                    p.alu();
                }
                p.ld_imm(1, 7.5);
                p.st(1, flag);
            }
            p.barrier(0);
            p.ld(2, flag);
            p.add(3, 2, 2);
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs);
        let stats = cl.run(100_000);
        for pe in &cl.pes {
            assert_eq!(pe.reg(3), 15.0);
        }
        // The 31 early arrivals piled up synch stalls.
        assert!(stats.stall_synch > 31 * 150, "synch={}", stats.stall_synch);
    }

    #[test]
    fn ipc_near_one_for_pure_compute() {
        let cfg = ClusterConfig::tiny();
        let progs = programs_for(&cfg, |_| {
            let mut p = Program::new();
            p.ld_imm(1, 1.0);
            p.ld_imm(2, 1.5);
            for _ in 0..500 {
                p.fmac(3, 1, 2);
            }
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs);
        let stats = cl.run(10_000);
        assert!(stats.ipc() > 0.95, "ipc={}", stats.ipc());
        assert_eq!(stats.flops, 32 * 500 * 2);
    }

    #[test]
    fn local_loads_hit_single_cycle_amat() {
        let cfg = ClusterConfig::tiny();
        let l1 = L1Memory::new(&cfg);
        // Each PE streams loads from its own 4 banks (chunk-of-4
        // interleaved assignment → all local).
        let base = l1.map.interleaved_base();
        let bf = cfg.banking_factor as u32;
        let nb = cfg.num_banks() as u32;
        let progs = programs_for(&cfg, |i| {
            let mut p = Program::new();
            for k in 0..64u32 {
                let word = base + (k * nb) + bf * i as u32 + (k % bf);
                p.ld(1 + (k % 8) as u8, word);
            }
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs);
        let stats = cl.run(100_000);
        assert_eq!(stats.reqs_per_class[0], 32 * 64, "all local");
        assert!(stats.amat_per_class[0] < 1.5, "amat={}", stats.amat_per_class[0]);
    }

    #[test]
    fn remote_group_loads_have_higher_amat() {
        let cfg = ClusterConfig::tiny();
        let nb = cfg.num_banks() as u32;
        let base = L1Memory::new(&cfg).map.interleaved_base();
        // All PEs of group 0 read words living in group 1's banks.
        let progs = programs_for(&cfg, |i| {
            let mut p = Program::new();
            if i < 16 {
                for k in 0..32u32 {
                    // bank in the second half (group 1), unique per PE
                    let bank = 64 + (i as u32 * 2 + k) % 64;
                    let word = base + bank + (k / 8) * nb;
                    p.ld(1 + (k % 8) as u8, word);
                }
            }
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs);
        let stats = cl.run(100_000);
        assert!(stats.reqs_per_class[3] > 0);
        assert!(
            stats.amat_per_class[3] >= 9.0,
            "remote amat {} < zero-load",
            stats.amat_per_class[3]
        );
    }

    /// Quick in-module smoke of the sharded engine; the exhaustive
    /// serial-vs-parallel matrix lives in rust/tests/parallel_equiv.rs.
    #[test]
    fn parallel_engine_matches_serial_on_tiny_store_load() {
        let cfg = ClusterConfig::tiny();
        let base = L1Memory::new(&cfg).map.interleaved_base();
        let out = base + 256;
        let build = |cfg: &ClusterConfig| {
            programs_for(cfg, |i| {
                let mut p = Program::new();
                p.ld_imm(1, 100.0 + i as f32);
                p.st(1, base + i as u32);
                p.barrier(0);
                let n = base + ((i as u32 + 1) % 32);
                p.ld(2, n);
                p.st(2, out + i as u32);
                p.halt();
                p
            })
        };
        let mut serial = Cluster::new(cfg.clone(), build(&cfg));
        let s_stats = serial.run(10_000);
        for threads in [1usize, 2, 4] {
            let mut par = Cluster::new(cfg.clone(), build(&cfg));
            let p_stats = par.run_parallel(10_000, threads);
            assert_eq!(s_stats, p_stats, "stats diverge at {threads} threads");
            assert_eq!(
                serial.l1.read_slice(out, 32),
                par.l1.read_slice(out, 32),
                "memory image diverges at {threads} threads"
            );
        }
    }

    #[test]
    fn burst_roundtrip_matches_singles_with_fewer_grants() {
        // Each PE burst-stores 4 words into its own banking-factor
        // window, barriers, then burst-loads its neighbour's window.
        // The memory image must match the single-word program exactly,
        // and the burst run must not be slower.
        let cfg = ClusterConfig::tiny();
        let base = L1Memory::new(&cfg).map.interleaved_base();
        let out = base + 512;
        let bf = cfg.banking_factor as u32; // 4 = MAX_BURST_WORDS
        let build = |cfg: &ClusterConfig, burst: bool| {
            programs_for(cfg, |i| {
                let window = |pe: u32| base + bf * pe;
                let mut p = Program::new();
                for k in 0..bf {
                    p.ld_imm(1 + k as u8, (i as u32 * 10 + k) as f32);
                }
                if burst {
                    p.st_burst(1, window(i as u32), bf as u8);
                } else {
                    for k in 0..bf {
                        p.st(1 + k as u8, window(i as u32) + k);
                    }
                }
                p.barrier(0);
                let n = (i as u32 + 1) % cfg.num_pes() as u32;
                if burst {
                    p.ld_burst(8, window(n), bf as u8);
                    p.st_burst(8, out + bf * i as u32, bf as u8);
                } else {
                    for k in 0..bf {
                        p.ld(8 + k as u8, window(n) + k);
                        p.st(8 + k as u8, out + bf * i as u32 + k);
                    }
                }
                p.halt();
                p
            })
        };
        let mut single = Cluster::new(cfg.clone(), build(&cfg, false));
        let s = single.run(100_000);
        let mut burst = Cluster::new(cfg.clone(), build(&cfg, true));
        let b = burst.run(100_000);
        assert_eq!(
            single.l1.read_slice(out, 128),
            burst.l1.read_slice(out, 128),
            "burst and single-word programs must produce the same image"
        );
        assert!(b.cycles <= s.cycles, "burst {} > single {}", b.cycles, s.cycles);
        // Split accounting: the burst run reports its traffic, the
        // single-word run reports none.
        assert_eq!(s.burst_reqs_per_class, [0; 4]);
        assert_eq!(s.burst_words_per_class, [0; 4]);
        assert!(b.burst_reqs_per_class.iter().sum::<u64>() > 0);
        for c in 0..4 {
            assert!(b.burst_reqs_per_class[c] <= b.reqs_per_class[c]);
        }
    }

    /// Satellite: ClassStats burst/single split sums exactly to the
    /// legacy totals on a burst-off run — same trace as an old binary
    /// would execute, and `reqs - burst_reqs == reqs`.
    #[test]
    fn burst_off_run_reports_pure_single_word_traffic() {
        let cfg = ClusterConfig::tiny();
        let base = L1Memory::new(&cfg).map.interleaved_base();
        let progs = programs_for(&cfg, |i| {
            let mut p = Program::new();
            p.ld_imm(1, i as f32);
            p.st(1, base + i as u32);
            p.barrier(0);
            p.ld(2, base + ((i as u32 + 7) % 32));
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs);
        let stats = cl.run(100_000);
        assert_eq!(stats.burst_reqs_per_class, [0; 4]);
        assert_eq!(stats.burst_words_per_class, [0; 4]);
        let singles: u64 = stats
            .reqs_per_class
            .iter()
            .zip(&stats.burst_reqs_per_class)
            .map(|(r, b)| r - b)
            .sum();
        assert_eq!(singles, stats.reqs_per_class.iter().sum::<u64>());
    }

    /// Satellite: a burst racing a DMA write into the same banks stays
    /// deterministic — serial and sharded engines agree bit-for-bit on
    /// the stats and the final image.
    #[test]
    fn burst_racing_dma_write_is_deterministic() {
        use crate::dma::{hbm_image_clear, hbm_image_stage, DmaDescriptor};
        let cfg = ClusterConfig::tiny();
        let base = L1Memory::new(&cfg).map.interleaved_base();
        let out = base + 512;
        let bf = cfg.banking_factor as u32;
        let build = |cfg: &ClusterConfig| {
            programs_for(cfg, |i| {
                let mut p = Program::new();
                if i == 0 {
                    p.push(Op::DmaStart { id: 0 });
                }
                // Racing burst stores into the DMA's destination window
                // while the transfer is in flight...
                p.st_burst(1, base + bf * i as u32, bf as u8);
                p.push(Op::DmaWait { id: 0 });
                // ...then read the settled words back with a burst.
                p.ld_burst(4, base + bf * i as u32, bf as u8);
                p.st_burst(4, out + bf * i as u32, bf as u8);
                p.halt();
                p
            })
        };
        let run = |threads: usize| {
            hbm_image_clear();
            let data: Vec<f32> = (0..128).map(|i| 1000.0 + i as f32).collect();
            hbm_image_stage(0, &data);
            let mut cl = Cluster::new(cfg.clone(), build(&cfg)).with_dma();
            cl.dma.as_mut().unwrap().register(DmaDescriptor {
                l1_word: base,
                mem_byte: 0,
                words: 128,
                to_l1: true,
            });
            let stats = cl.run_threads(100_000, threads);
            (stats, cl.l1.read_slice(out, 128))
        };
        let (s_stats, s_img) = run(1);
        for threads in [2usize, 4] {
            let (p_stats, p_img) = run(threads);
            assert_eq!(s_stats, p_stats, "stats diverge at {threads} threads");
            assert_eq!(s_img, p_img, "image diverges at {threads} threads");
        }
    }

    #[test]
    fn dma_start_wait_roundtrip_from_trace() {
        use crate::dma::{hbm_image_clear, hbm_image_stage, DmaDescriptor};
        hbm_image_clear();
        let cfg = ClusterConfig::tiny();
        let base = L1Memory::new(&cfg).map.interleaved_base();
        let progs = programs_for(&cfg, |i| {
            let mut p = Program::new();
            if i == 0 {
                p.push(Op::DmaStart { id: 0 });
            }
            p.push(Op::DmaWait { id: 0 });
            // After the DMA, each PE loads one transferred word.
            p.ld(1, base + i as u32);
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs).with_dma();
        let data: Vec<f32> = (0..256).map(|i| i as f32 + 0.25).collect();
        hbm_image_stage(0, &data);
        cl.dma.as_mut().unwrap().register(DmaDescriptor {
            l1_word: base,
            mem_byte: 0,
            words: 256,
            to_l1: true,
        });
        cl.run(100_000);
        for (i, pe) in cl.pes.iter().enumerate() {
            assert_eq!(pe.reg(1), i as f32 + 0.25);
        }
    }
}
