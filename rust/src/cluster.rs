//! Cluster composition: PEs + hierarchical interconnect + banked L1 +
//! fork-join barriers + the HBML DMA subsystem, advanced in lock-step one
//! cycle at a time (Sec. 4.2, Sec. 7).
//!
//! The fork-join SPMD model of the paper: after "boot" every PE runs its
//! trace concurrently; `Op::Barrier` arrivals are **real atomic
//! fetch&adds** on a Tile-local counter word (so the 8 PEs of a Tile
//! serialize at their bank, as in hardware), and the cross-Tile
//! aggregation + WFI wake-up broadcast is charged as the configurable
//! `barrier_wakeup` latency.

use std::collections::HashMap;

use crate::config::ClusterConfig;
use crate::dma::DmaSubsystem;
use crate::interconnect::{Interconnect, NumaClass, ReqKind, Response};
use crate::isa::Program;
use crate::memory::L1Memory;
use crate::pe::{Action, Pe, PeStats};

/// Word offset inside each Tile's sequential region reserved for the
/// barrier arrival counter (kernel traces must not touch it).
pub const BARRIER_SLOT: u32 = 0;

#[derive(Debug, Default)]
struct BarrierSlot {
    arrived: u32,
    waiting: Vec<u32>,
    release_at: Option<u64>,
}

/// Aggregated run results (feeds Fig. 14a, Table 6, the headline numbers).
#[derive(Debug, Clone)]
pub struct RunStats {
    pub cycles: u64,
    pub instructions: u64,
    pub flops: u64,
    pub num_pes: usize,
    pub freq_mhz: f64,
    pub stall_raw: u64,
    pub stall_lsu: u64,
    pub stall_ctrl: u64,
    pub stall_synch: u64,
    pub loads: u64,
    pub stores: u64,
    pub atomics: u64,
    /// Measured AMAT over all L1 requests (cycles).
    pub amat: f64,
    /// Measured AMAT per NUMA class.
    pub amat_per_class: [f64; 4],
    pub reqs_per_class: [u64; 4],
}

impl RunStats {
    /// Instructions per cycle per PE (Fig. 14a's headline metric).
    pub fn ipc(&self) -> f64 {
        self.instructions as f64 / (self.cycles as f64 * self.num_pes as f64)
    }
    /// Fraction of PE-cycles in each category; sums to ≤ 1 (the remainder
    /// is post-halt idle of early-finishing PEs).
    pub fn fraction(&self, count: u64) -> f64 {
        count as f64 / (self.cycles as f64 * self.num_pes as f64)
    }
    /// Achieved GFLOP/s at the configured frequency.
    pub fn gflops(&self) -> f64 {
        self.flops as f64 / self.cycles as f64 * self.freq_mhz / 1000.0
    }
}

/// The simulated cluster.
pub struct Cluster {
    pub cfg: ClusterConfig,
    pub l1: L1Memory,
    pub icn: Interconnect,
    pub pes: Vec<Pe>,
    pub dma: Option<DmaSubsystem>,
    barriers: HashMap<u16, BarrierSlot>,
    dma_waiters: Vec<(u32, u16)>,
    pub cycle: u64,
}

impl Cluster {
    /// Build a cluster with one program per PE (`programs.len()` must be
    /// `cfg.num_pes()`).
    pub fn new(cfg: ClusterConfig, programs: Vec<Program>) -> Self {
        assert_eq!(programs.len(), cfg.num_pes(), "one program per PE");
        let l1 = L1Memory::new(&cfg);
        let icn = Interconnect::new(&cfg);
        let ppt = cfg.hierarchy.pes_per_tile;
        let pes = programs
            .into_iter()
            .enumerate()
            .map(|(i, p)| Pe::new(i as u32, (i / ppt) as u32, cfg.tx_table_entries as u32, p))
            .collect();
        Cluster {
            cfg,
            l1,
            icn,
            pes,
            dma: None,
            barriers: HashMap::new(),
            dma_waiters: Vec::new(),
            cycle: 0,
        }
    }

    /// Attach the HBML (DMA + HBM2E) subsystem.
    pub fn with_dma(mut self) -> Self {
        self.dma = Some(DmaSubsystem::new(&self.cfg));
        self
    }

    /// Barrier-counter word address for a Tile (sequential region slot 0).
    fn barrier_addr(&self, tile: u32) -> u32 {
        self.l1.map.seq_base_of_tile(tile as usize) + BARRIER_SLOT
    }

    fn apply_response(
        pes: &mut [Pe],
        barriers: &mut HashMap<u16, BarrierSlot>,
        r: Response,
    ) {
        let pe = &mut pes[r.core as usize];
        match r.kind {
            ReqKind::Read { rd } => pe.complete_load(rd, r.value),
            ReqKind::Write => pe.complete_ack(),
            ReqKind::Amo => {
                pe.complete_ack();
                if r.tag != 0 {
                    // Barrier arrival atomic acked → count it.
                    let slot = barriers.entry((r.tag - 1) as u16).or_default();
                    slot.arrived += 1;
                    slot.waiting.push(r.core);
                }
            }
        }
    }

    /// Advance a single cycle.
    pub fn step(&mut self) {
        let now = self.cycle;

        // 1. Deliver L1 responses due this cycle.
        let pes = &mut self.pes;
        let barriers = &mut self.barriers;
        self.icn
            .drain_responses(now, |r| Self::apply_response(pes, barriers, r));

        // 2. Barrier release: all arrived → broadcast wake after the
        //    aggregation/WFI latency.
        let expected = self.pes.len() as u32;
        for slot in self.barriers.values_mut() {
            if slot.arrived == expected && slot.release_at.is_none() {
                slot.release_at = Some(now + self.cfg.barrier_wakeup as u64);
            }
            if slot.release_at == Some(now) {
                for &pe in &slot.waiting {
                    self.pes[pe as usize].wake();
                }
                slot.waiting.clear();
                slot.arrived = 0;
                slot.release_at = None;
            }
        }

        // 3. DMA / HBM progress; wake DmaWait-parked PEs.
        if let Some(dma) = &mut self.dma {
            dma.step(now, &mut self.l1);
            let pes = &mut self.pes;
            self.dma_waiters.retain(|&(pe, id)| {
                if dma.is_done(id) {
                    pes[pe as usize].wake();
                    false
                } else {
                    true
                }
            });
        }

        // 4. PE issue phase.
        for i in 0..self.pes.len() {
            let action = self.pes[i].try_issue();
            match action {
                Action::None => {}
                Action::Load { rd, addr } => {
                    let bank = self.l1.map.map(addr);
                    let tile = self.pes[i].tile as usize;
                    self.icn
                        .push_request(now, i as u32, tile, ReqKind::Read { rd }, 0.0, bank, 0);
                }
                Action::Store { value, addr } => {
                    let bank = self.l1.map.map(addr);
                    let tile = self.pes[i].tile as usize;
                    self.icn
                        .push_request(now, i as u32, tile, ReqKind::Write, value, bank, 0);
                }
                Action::AmoAdd { value, addr } => {
                    let bank = self.l1.map.map(addr);
                    let tile = self.pes[i].tile as usize;
                    self.icn
                        .push_request(now, i as u32, tile, ReqKind::Amo, value, bank, 0);
                }
                Action::BarrierArrive { id } => {
                    let tile = self.pes[i].tile;
                    let bank = self.l1.map.map(self.barrier_addr(tile));
                    self.icn.push_request(
                        now,
                        i as u32,
                        tile as usize,
                        ReqKind::Amo,
                        1.0,
                        bank,
                        id as u32 + 1,
                    );
                }
                Action::DmaStart { id } => {
                    let dma = self
                        .dma
                        .as_mut()
                        .expect("trace uses DMA but cluster built without with_dma()");
                    dma.start(id, now);
                }
                Action::DmaWait { id } => {
                    let done = self.dma.as_ref().map(|d| d.is_done(id)).unwrap_or(true);
                    if done {
                        self.pes[i].wake();
                    } else {
                        self.dma_waiters.push((i as u32, id));
                    }
                }
            }
        }

        // 5. Interconnect arbitration + bank accesses.
        self.icn.step(now, &mut self.l1);

        self.cycle += 1;
    }

    /// All PEs halted, no requests in flight, DMA drained.
    pub fn done(&self) -> bool {
        self.pes.iter().all(|p| p.done())
            && self.icn.inflight() == 0
            && self.dma.as_ref().map(|d| d.idle()).unwrap_or(true)
    }

    /// Run to completion (or `max_cycles`); returns aggregated stats.
    pub fn run(&mut self, max_cycles: u64) -> RunStats {
        while !self.done() && self.cycle < max_cycles {
            self.step();
        }
        assert!(
            self.done(),
            "cluster did not finish within {max_cycles} cycles (possible deadlock)"
        );
        self.stats()
    }

    /// Aggregate statistics at the current cycle.
    pub fn stats(&self) -> RunStats {
        let mut agg = PeStats::default();
        for pe in &self.pes {
            let s = &pe.stats;
            agg.issued += s.issued;
            agg.flops += s.flops;
            agg.loads += s.loads;
            agg.stores += s.stores;
            agg.atomics += s.atomics;
            agg.stall_raw += s.stall_raw;
            agg.stall_lsu += s.stall_lsu;
            agg.stall_ctrl += s.stall_ctrl;
            agg.stall_synch += s.stall_synch;
        }
        let ic = &self.icn.stats;
        RunStats {
            cycles: self.cycle,
            instructions: agg.issued,
            flops: agg.flops,
            num_pes: self.pes.len(),
            freq_mhz: self.cfg.freq_mhz,
            stall_raw: agg.stall_raw,
            stall_lsu: agg.stall_lsu,
            stall_ctrl: agg.stall_ctrl,
            stall_synch: agg.stall_synch,
            loads: agg.loads,
            stores: agg.stores,
            atomics: agg.atomics,
            amat: ic.amat(),
            amat_per_class: [
                ic.per_class[0].amat(),
                ic.per_class[1].amat(),
                ic.per_class[2].amat(),
                ic.per_class[3].amat(),
            ],
            reqs_per_class: [
                ic.per_class[0].count,
                ic.per_class[1].count,
                ic.per_class[2].count,
                ic.per_class[3].count,
            ],
        }
    }

    /// Convenience: the NUMA class histogram as fractions.
    pub fn class_mix(&self) -> [f64; 4] {
        let total: u64 = self.icn.stats.per_class.iter().map(|c| c.count).sum();
        let mut out = [0.0; 4];
        if total > 0 {
            for (i, c) in self.icn.stats.per_class.iter().enumerate() {
                out[i] = c.count as f64 / total as f64;
            }
        }
        let _ = NumaClass::Local;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Op, Program};

    fn programs_for(cfg: &ClusterConfig, f: impl Fn(usize) -> Program) -> Vec<Program> {
        (0..cfg.num_pes()).map(f).collect()
    }

    #[test]
    fn every_pe_executes_and_halts() {
        let cfg = ClusterConfig::tiny();
        let progs = programs_for(&cfg, |i| {
            let mut p = Program::new();
            p.ld_imm(1, i as f32);
            p.ld_imm(2, 2.0);
            p.mul(3, 1, 2);
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs);
        let stats = cl.run(1000);
        assert_eq!(stats.instructions, 32 * 3);
        for (i, pe) in cl.pes.iter().enumerate() {
            assert_eq!(pe.reg(3), i as f32 * 2.0);
        }
    }

    #[test]
    fn store_then_load_roundtrip_through_l1() {
        let cfg = ClusterConfig::tiny();
        let base = L1Memory::new(&cfg).map.interleaved_base();
        let out = base + 256; // separate output region (no write race)
        let progs = programs_for(&cfg, |i| {
            let mut p = Program::new();
            p.ld_imm(1, 100.0 + i as f32);
            p.st(1, base + i as u32);
            p.barrier(0);
            // read the neighbour's word (wraps) and store to the output
            let n = base + ((i as u32 + 1) % 32);
            p.ld(2, n);
            p.st(2, out + i as u32);
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs);
        cl.run(10_000);
        for i in 0..32u32 {
            let got = cl.l1.read(out + i);
            assert_eq!(got, 100.0 + ((i + 1) % 32) as f32, "word {i}");
        }
    }

    #[test]
    fn barrier_synchronizes_all_pes() {
        let cfg = ClusterConfig::tiny();
        // PE 0 does a long prologue; all others wait at the barrier. After
        // the barrier each PE loads the word PE 0 wrote.
        let base = L1Memory::new(&cfg).map.interleaved_base();
        let flag = base + 500;
        let progs = programs_for(&cfg, |i| {
            let mut p = Program::new();
            if i == 0 {
                for _ in 0..200 {
                    p.alu();
                }
                p.ld_imm(1, 7.5);
                p.st(1, flag);
            }
            p.barrier(0);
            p.ld(2, flag);
            p.add(3, 2, 2);
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs);
        let stats = cl.run(100_000);
        for pe in &cl.pes {
            assert_eq!(pe.reg(3), 15.0);
        }
        // The 31 early arrivals piled up synch stalls.
        assert!(stats.stall_synch > 31 * 150, "synch={}", stats.stall_synch);
    }

    #[test]
    fn ipc_near_one_for_pure_compute() {
        let cfg = ClusterConfig::tiny();
        let progs = programs_for(&cfg, |_| {
            let mut p = Program::new();
            p.ld_imm(1, 1.0);
            p.ld_imm(2, 1.5);
            for _ in 0..500 {
                p.fmac(3, 1, 2);
            }
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs);
        let stats = cl.run(10_000);
        assert!(stats.ipc() > 0.95, "ipc={}", stats.ipc());
        assert_eq!(stats.flops, 32 * 500 * 2);
    }

    #[test]
    fn local_loads_hit_single_cycle_amat() {
        let cfg = ClusterConfig::tiny();
        let l1 = L1Memory::new(&cfg);
        // Each PE streams loads from its own 4 banks (chunk-of-4
        // interleaved assignment → all local).
        let base = l1.map.interleaved_base();
        let bf = cfg.banking_factor as u32;
        let nb = cfg.num_banks() as u32;
        let progs = programs_for(&cfg, |i| {
            let mut p = Program::new();
            for k in 0..64u32 {
                let word = base + (k * nb) + bf * i as u32 + (k % bf);
                p.ld(1 + (k % 8) as u8, word);
            }
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs);
        let stats = cl.run(100_000);
        assert_eq!(stats.reqs_per_class[0], 32 * 64, "all local");
        assert!(stats.amat_per_class[0] < 1.5, "amat={}", stats.amat_per_class[0]);
    }

    #[test]
    fn remote_group_loads_have_higher_amat() {
        let cfg = ClusterConfig::tiny();
        let nb = cfg.num_banks() as u32;
        let base = L1Memory::new(&cfg).map.interleaved_base();
        // All PEs of group 0 read words living in group 1's banks.
        let progs = programs_for(&cfg, |i| {
            let mut p = Program::new();
            if i < 16 {
                for k in 0..32u32 {
                    // bank in the second half (group 1), unique per PE
                    let bank = 64 + (i as u32 * 2 + k) % 64;
                    let word = base + bank + (k / 8) * nb;
                    p.ld(1 + (k % 8) as u8, word);
                }
            }
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs);
        let stats = cl.run(100_000);
        assert!(stats.reqs_per_class[3] > 0);
        assert!(
            stats.amat_per_class[3] >= 9.0,
            "remote amat {} < zero-load",
            stats.amat_per_class[3]
        );
    }

    #[test]
    fn dma_start_wait_roundtrip_from_trace() {
        use crate::dma::{hbm_image_clear, hbm_image_stage, DmaDescriptor};
        hbm_image_clear();
        let cfg = ClusterConfig::tiny();
        let base = L1Memory::new(&cfg).map.interleaved_base();
        let progs = programs_for(&cfg, |i| {
            let mut p = Program::new();
            if i == 0 {
                p.push(Op::DmaStart { id: 0 });
            }
            p.push(Op::DmaWait { id: 0 });
            // After the DMA, each PE loads one transferred word.
            p.ld(1, base + i as u32);
            p.halt();
            p
        });
        let mut cl = Cluster::new(cfg, progs).with_dma();
        let data: Vec<f32> = (0..256).map(|i| i as f32 + 0.25).collect();
        hbm_image_stage(0, &data);
        cl.dma.as_mut().unwrap().register(DmaDescriptor {
            l1_word: base,
            mem_byte: 0,
            words: 256,
            to_l1: true,
        });
        cl.run(100_000);
        for (i, pe) in cl.pes.iter().enumerate() {
            assert_eq!(pe.reg(1), i as f32 + 0.25);
        }
    }
}
