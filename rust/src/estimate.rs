//! `Session::estimate()` — the calibrated analytic fast path.
//!
//! Predicts a workload's [`RunStats`] without running the cycle-accurate
//! engine at the target scale, in three layers:
//!
//! 1. **Exact census** ([`model_run`], the `census` half): every PE
//!    program is linear (branches fall through with a refetch bubble;
//!    the first `Halt` ends the trace), so instruction, FLOP, load /
//!    store / atomic and per-NUMA-class request counts are *computable*,
//!    not estimated — the census replays the engine's own counting rules
//!    (`Pe::count_issue`, `route_action`, `Topology::classify`) over the
//!    static trace. These fields land in the report bit-exact, which is
//!    what lets `tools/report_diff.py` hold them to zero drift.
//! 2. **Analytic schedule** (the timing half): a per-PE O(ops)
//!    mini-schedule replays the core's issue rules — RAW/WAW readiness,
//!    the `tx_table_entries` LSU cap, the post-branch `CTRL_BUBBLE` —
//!    against per-class effective latencies `L(c) = zero_load(c) +
//!    contention(c)`, with contention from the paper's closed-form
//!    arbitration model (`amat::HierSpec::level_contention_at`) at the
//!    census-derived injection rates. Barrier-delimited segments combine
//!    bulk-synchronously: each phase costs the slowest PE's segment plus
//!    the arrival + wake-up overhead, and the headroom of faster PEs is
//!    charged to their predicted `stall_synch`.
//! 3. **Calibration** ([`calibrated_stats`]): one cycle-accurate run at
//!    `Scale::Fast` anchors the model. Every approximate field F is
//!    reported as `actual_fast(F) × model_target(F) / model_fast(F)` —
//!    systematic model bias cancels in the ratio, so the estimate is
//!    *exact by construction* when the target scale is the calibration
//!    scale, and tracks the engine to the stated bound (EXPERIMENTS.md
//!    §Estimate accuracy: 10 % relative on off-saturation configs) when
//!    extrapolating to full scale.
//!
//! The model intentionally does not chase saturated interconnect rows
//! (where closed-form contention diverges, see `amat.rs`). HBML traffic
//! (the double-buffered workloads) gets a fluid model of the DMA engine
//! ([`dma_timeline`]): descriptor starts serialize through the frontend
//! (`CONFIG_CYCLES` apiece) and concurrently-active transfers share the
//! aggregate backend/channel bandwidth processor-sharing style. The
//! resulting completions live on the *global* phase clock, so every PE's
//! `DmaWait` sees them — not just the PE that issued the start.
//! Cycle-level burst/bank arbitration is still left to calibration.

use std::collections::HashMap;

use crate::amat::HierSpec;
use crate::cluster::{RunStats, BARRIER_SLOT};
use crate::config::ClusterConfig;
use crate::dma::CONFIG_CYCLES;
use crate::isa::{Op, OpClass, Program, CTRL_BUBBLE, NUM_REGS};
use crate::kernels::Staged;
use crate::memory::AddressMap;

/// Exact static counts over a staged workload (see module docs, layer 1).
#[derive(Debug, Clone, Copy, Default)]
pub struct Census {
    pub instructions: u64,
    pub flops: u64,
    pub loads: u64,
    pub stores: u64,
    pub atomics: u64,
    pub branches: u64,
    /// Barrier arrivals over all PEs.
    pub barriers: u64,
    /// L1 requests per NUMA class — loads, stores, explicit atomics and
    /// the barrier-arrival atomics, classified exactly as
    /// `cluster::route_action` would. Burst instructions contribute one
    /// request per consecutive-bank run of the same `map_burst` split
    /// the engine performs.
    pub reqs_per_class: [u64; 4],
    /// Multi-word runs per class (subset of `reqs_per_class`) — mirrors
    /// the engine's `ClassStats` burst split exactly.
    pub burst_reqs_per_class: [u64; 4],
    /// Words those runs move.
    pub burst_words_per_class: [u64; 4],
    /// Bytes the trace's `DmaStart`s will move through the HBML.
    pub dma_bytes: u64,
}

/// Census + analytic-schedule prediction for one staged workload.
#[derive(Debug, Clone)]
pub struct ModelRun {
    pub census: Census,
    pub cycles: f64,
    pub stall_raw: f64,
    pub stall_lsu: f64,
    /// Exact: every branch costs precisely `CTRL_BUBBLE` refetch stalls.
    pub stall_ctrl: f64,
    pub stall_synch: f64,
    pub amat: f64,
    pub amat_per_class: [f64; 4],
}

/// NUMA classification mirroring `interconnect::Topology::classify` —
/// kept as plain math on the config so the estimate does not need to
/// build an interconnect.
#[derive(Clone, Copy)]
struct Numa {
    tiles_per_subgroup: usize,
    tiles_per_group: usize,
    banks_per_tile: usize,
    pes_per_tile: usize,
}

impl Numa {
    fn new(cfg: &ClusterConfig) -> Self {
        Numa {
            tiles_per_subgroup: cfg.hierarchy.tiles_per_subgroup,
            tiles_per_group: cfg.hierarchy.tiles_per_group(),
            banks_per_tile: cfg.banks_per_tile(),
            pes_per_tile: cfg.hierarchy.pes_per_tile,
        }
    }

    fn classify(&self, src_tile: usize, dst_tile: usize) -> usize {
        if src_tile == dst_tile {
            return 0; // Local
        }
        if src_tile / self.tiles_per_group != dst_tile / self.tiles_per_group {
            return 3; // RemoteGroup
        }
        let s_sg = (src_tile % self.tiles_per_group) / self.tiles_per_subgroup;
        let d_sg = (dst_tile % self.tiles_per_group) / self.tiles_per_subgroup;
        if s_sg == d_sg {
            1 // SubGroup
        } else {
            2 // Group
        }
    }

    /// Class of a word access issued from `tile`.
    fn class_of(&self, map: &AddressMap, tile: usize, addr: u32) -> usize {
        let dst = map.map(addr).bank as usize / self.banks_per_tile;
        self.classify(tile, dst)
    }

    /// Class of an already-mapped bank (burst runs are classified by
    /// their base bank, like the engine's per-run requests).
    fn class_of_bank(&self, tile: usize, bank: crate::memory::BankAddr) -> usize {
        self.classify(tile, bank.bank as usize / self.banks_per_tile)
    }
}

/// The engine's NUMA classes onto the [`HierSpec`] contention levels.
/// `HierSpec` collapses degenerate hierarchy levels (β, γ or δ = 1)
/// while the engine always reports four classes; a class's level is the
/// number of *live* hierarchy crossings at or below it. Classes whose
/// crossing is degenerate can never carry traffic, so their mapping is
/// moot.
fn level_of_class(spec: &HierSpec, class: usize) -> usize {
    let mut level = 0;
    if class >= 1 && spec.beta > 1 {
        level += 1;
    }
    if class >= 2 && spec.gamma > 1 {
        level += 1;
    }
    if class >= 3 && spec.delta > 1 {
        level += 1;
    }
    level
}

fn hier_of(cfg: &ClusterConfig) -> HierSpec {
    let h = &cfg.hierarchy;
    HierSpec {
        alpha: h.pes_per_tile,
        beta: h.tiles_per_subgroup,
        gamma: h.subgroups_per_group,
        delta: h.groups,
        banking: cfg.banking_factor,
    }
}

/// Pipeline-fill tail of one HBML transfer: command/read latency through
/// the AXI tree plus the HBM access pipeline, in cluster cycles.
const DMA_TAIL_CYCLES: f64 = 100.0;

/// Global-clock DMA completion estimates shared by every PE's schedule.
/// The engine has a single DMA frontend — one PE issues the starts but
/// *every* PE parks on the completions — so the timeline lives on the
/// global phase clock, anchored per barrier-delimited phase by
/// `phase_start`. Empty when the staged workload moves no HBML traffic.
#[derive(Debug, Clone, Default)]
struct DmaTimeline {
    /// Descriptor id → estimated completion on the global clock.
    done: HashMap<u16, f64>,
    /// Global start offset of each barrier-delimited phase.
    phase_start: Vec<f64>,
}

impl DmaTimeline {
    /// Completion deadline of `id` on the local clock of phase `seg`.
    fn local_done(&self, id: u16, seg: usize) -> Option<f64> {
        let g = *self.done.get(&id)?;
        Some(g - self.phase_start.get(seg).copied().unwrap_or(0.0))
    }
}

/// Global start offset of each bulk-synchronous phase, under the same
/// assembly rule [`model_run`] uses: a phase costs its slowest PE's
/// segment plus the wake-up broadcast and the release cycle.
fn phase_starts(scheds: &[PeSched], wakeup: f64) -> Vec<f64> {
    let n_phases = scheds.iter().map(|s| s.segments.len()).max().unwrap_or(1);
    let mut starts = Vec::with_capacity(n_phases);
    let mut at = 0.0f64;
    for k in 0..n_phases {
        starts.push(at);
        let longest =
            scheds.iter().filter_map(|s| s.segments.get(k).copied()).fold(0.0f64, f64::max);
        at += longest + wakeup + 1.0;
    }
    starts
}

/// Fluid model of the HBML engine over the schedule's recorded
/// `DmaStart` points: the frontend programs one descriptor per
/// [`CONFIG_CYCLES`], then concurrently-active transfers processor-share
/// the aggregate bandwidth — the lesser of the main-memory peak and the
/// per-SubGroup 512-bit backend ports (64 B/cycle each, see `axi.rs`).
/// Per-cycle burst/bank arbitration is deliberately not replayed;
/// calibration absorbs that residual.
fn dma_timeline(
    cfg: &ClusterConfig,
    scheds: &[PeSched],
    desc_bytes: &HashMap<u16, u64>,
    phase_start: Vec<f64>,
) -> DmaTimeline {
    // Starts on the global clock, in frontend (issue-time) order.
    let mut starts: Vec<(f64, u16)> = Vec::new();
    for s in scheds {
        for &(id, seg, local) in &s.dma_starts {
            let base = phase_start.get(seg).copied().unwrap_or(0.0);
            starts.push((base + local, id));
        }
    }
    starts.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));

    // peak GB/s = bytes/ns; at freq_mhz the cluster sees
    // peak × 1000 / freq bytes per cycle.
    let peak = cfg.ddr.peak_gbps_total() * 1000.0 / cfg.freq_mhz;
    let ports = (cfg.hierarchy.num_subgroups().max(1) * 64) as f64;
    let bw = peak.min(ports).max(1e-9);

    struct Xfer {
        id: u16,
        ready: f64,
        left: f64,
    }
    // Frontend serialization: back-to-back starts queue behind one
    // CSR-programming slot, CONFIG_CYCLES apiece.
    let mut frontend_free = 0.0f64;
    let mut xfers: Vec<Xfer> = Vec::with_capacity(starts.len());
    for (at, id) in starts {
        let ready = frontend_free.max(at) + CONFIG_CYCLES as f64;
        frontend_free = ready;
        let bytes = desc_bytes.get(&id).copied().unwrap_or(0) as f64;
        xfers.push(Xfer { id, ready, left: bytes.max(1.0) });
    }

    // Processor-sharing drain: active transfers split `bw` evenly (their
    // 1 KiB bursts stripe over the same channels), stepping between
    // arrival and completion events.
    let mut done: HashMap<u16, f64> = HashMap::new();
    let mut now = 0.0f64;
    while !xfers.is_empty() {
        let active = xfers.iter().filter(|x| x.ready <= now).count();
        if active == 0 {
            now = xfers.iter().map(|x| x.ready).fold(f64::INFINITY, f64::min);
            continue;
        }
        let rate = bw / active as f64;
        let next_ready =
            xfers.iter().filter(|x| x.ready > now).map(|x| x.ready).fold(f64::INFINITY, f64::min);
        let min_left =
            xfers.iter().filter(|x| x.ready <= now).map(|x| x.left).fold(f64::INFINITY, f64::min);
        let step_end = (now + min_left / rate).min(next_ready);
        for x in xfers.iter_mut().filter(|x| x.ready <= now) {
            x.left -= (step_end - now) * rate;
        }
        now = step_end;
        xfers.retain(|x| {
            if x.ready <= now && x.left <= 1e-6 {
                done.insert(x.id, now + DMA_TAIL_CYCLES);
                false
            } else {
                true
            }
        });
    }
    DmaTimeline { done, phase_start }
}

/// One PE's analytic schedule: barrier-delimited busy segments plus
/// per-cause stall predictions.
#[derive(Debug, Clone, Default)]
struct PeSched {
    /// Busy duration of each barrier-delimited phase; the last entry is
    /// the post-final-barrier (or whole-trace) segment including the
    /// outstanding-request drain.
    segments: Vec<f64>,
    stall_raw: f64,
    stall_lsu: f64,
    /// DmaWait park time (the barrier share of synch stalls is computed
    /// across PEs in [`model_run`]).
    dma_wait: f64,
    /// `DmaStart` issue points: (descriptor, phase index, local cycle) —
    /// the raw material for [`dma_timeline`].
    dma_starts: Vec<(u16, usize, f64)>,
}

/// Replay one program against per-class effective latencies (module
/// docs, layer 2). `lat[c]` is the full round-trip a class-`c` request
/// holds its transaction-table entry and destination register for.
fn schedule_pe(
    prog: &Program,
    tile: usize,
    map: &AddressMap,
    numa: &Numa,
    lat: &[f64; 4],
    tx_cap: usize,
    dma: &DmaTimeline,
) -> PeSched {
    let mut s = PeSched::default();
    let mut t = 0.0f64;
    let mut ready = [0.0f64; NUM_REGS];
    let mut tx: Vec<f64> = Vec::with_capacity(tx_cap);
    // Index of the barrier-delimited phase the local clock lives in.
    let mut seg = 0usize;

    // Wait until a transaction-table slot frees (the engine's Lsu stall).
    fn tx_admit(tx: &mut Vec<f64>, t: &mut f64, cap: usize, stall_lsu: &mut f64) {
        tx.retain(|&c| c > *t);
        if tx.len() >= cap {
            let earliest = tx.iter().copied().fold(f64::INFINITY, f64::min);
            if earliest > *t {
                *stall_lsu += earliest - *t;
                *t = earliest;
            }
            tx.retain(|&c| c > *t);
        }
    }

    for op in &prog.ops {
        match *op {
            Op::Ld { rd, addr } => {
                let rd = rd as usize;
                if ready[rd] > t {
                    s.stall_raw += ready[rd] - t; // WAW on the in-flight load
                    t = ready[rd];
                }
                tx_admit(&mut tx, &mut t, tx_cap, &mut s.stall_lsu);
                let done = t + lat[numa.class_of(map, tile, addr)];
                tx.push(done);
                ready[rd] = done;
                t += 1.0;
            }
            Op::LdBurst { rd, n, addr } => {
                let rd = rd as usize;
                let mut need = t;
                for k in 0..n as usize {
                    need = need.max(ready[rd + k]);
                }
                if need > t {
                    s.stall_raw += need - t;
                    t = need;
                }
                tx_admit(&mut tx, &mut t, tx_cap, &mut s.stall_lsu);
                // One table entry held until the slowest run returns;
                // the whole register window frees with it.
                let mut l = 0.0f64;
                map.map_burst(addr, n, |bank, _| {
                    l = l.max(lat[numa.class_of_bank(tile, bank)]);
                });
                let done = t + l;
                tx.push(done);
                for k in 0..n as usize {
                    ready[rd + k] = done;
                }
                t += 1.0;
            }
            Op::StBurst { rs, n, addr } => {
                let rs = rs as usize;
                let mut need = t;
                for k in 0..n as usize {
                    need = need.max(ready[rs + k]);
                }
                if need > t {
                    s.stall_raw += need - t;
                    t = need;
                }
                tx_admit(&mut tx, &mut t, tx_cap, &mut s.stall_lsu);
                let mut l = 0.0f64;
                map.map_burst(addr, n, |bank, _| {
                    l = l.max(lat[numa.class_of_bank(tile, bank)]);
                });
                tx.push(t + l);
                t += 1.0;
            }
            Op::St { rs, addr } | Op::AtomAdd { rs, addr } => {
                let rs = rs as usize;
                if ready[rs] > t {
                    s.stall_raw += ready[rs] - t;
                    t = ready[rs];
                }
                tx_admit(&mut tx, &mut t, tx_cap, &mut s.stall_lsu);
                tx.push(t + lat[numa.class_of(map, tile, addr)]);
                t += 1.0;
            }
            Op::LdImm { rd, .. } => {
                let rd = rd as usize;
                if ready[rd] > t {
                    s.stall_raw += ready[rd] - t;
                    t = ready[rd];
                }
                t += 1.0;
            }
            Op::Fmac { rd, ra, rb }
            | Op::Fnmac { rd, ra, rb }
            | Op::Mul { rd, ra, rb }
            | Op::Add { rd, ra, rb }
            | Op::Sub { rd, ra, rb } => {
                let need = ready[ra as usize].max(ready[rb as usize]).max(ready[rd as usize]);
                if need > t {
                    s.stall_raw += need - t;
                    t = need;
                }
                t += 1.0;
            }
            Op::Mov { rd, ra } => {
                let need = ready[ra as usize].max(ready[rd as usize]);
                if need > t {
                    s.stall_raw += need - t;
                    t = need;
                }
                t += 1.0;
            }
            Op::Alu => t += 1.0,
            Op::Branch => t += 1.0 + CTRL_BUBBLE as f64,
            Op::Barrier { .. } => {
                tx_admit(&mut tx, &mut t, tx_cap, &mut s.stall_lsu);
                // Segment ends when the arrival atomic lands on the
                // (Tile-local) counter bank.
                let seg_end = t + 1.0 + lat[0];
                s.segments.push(seg_end);
                t = 0.0;
                ready = [0.0; NUM_REGS];
                tx.clear();
                seg += 1;
            }
            Op::DmaStart { id } => {
                // One issue cycle at the core; the engine-side cost
                // (frontend serialization, bandwidth sharing) lives in
                // the shared [`DmaTimeline`], built from these points.
                t += 1.0;
                s.dma_starts.push((id, seg, t));
            }
            Op::DmaWait { id } => {
                t += 1.0;
                // Transfers stream on the global clock — convert onto
                // this phase's local clock before parking.
                if let Some(done) = dma.local_done(id, seg) {
                    if done > t {
                        s.dma_wait += done - t;
                        t = done;
                    }
                }
            }
            Op::Halt => break,
        }
    }
    // Final segment: the trace plus the drain of outstanding requests.
    let drain = tx.iter().copied().fold(t, f64::max);
    s.segments.push(drain);
    s
}

/// Exact census + analytic timing model of one staged workload on `cfg`
/// (module docs, layers 1–2).
pub fn model_run(cfg: &ClusterConfig, staged: &Staged) -> ModelRun {
    let map = AddressMap::new(cfg);
    let numa = Numa::new(cfg);
    let spec = hier_of(cfg);
    let num_pes = cfg.num_pes().max(1);

    // Per-descriptor byte counts: the census charges them at DmaStart
    // and the DMA timeline drains them through the fluid engine model.
    let mut desc_bytes: HashMap<u16, u64> = HashMap::new();
    if let Some(plan) = &staged.dma {
        for (i, d) in plan.descriptors.iter().enumerate() {
            desc_bytes.insert(i as u16, d.words as u64 * 4);
        }
    }

    // ---- layer 1: exact census -------------------------------------
    let mut c = Census::default();
    for (pe, prog) in staged.programs.iter().enumerate() {
        let tile = pe / numa.pes_per_tile;
        for op in &prog.ops {
            if matches!(op, Op::Halt) {
                break; // Halt retires the PE without issuing.
            }
            c.instructions += 1;
            c.flops += op.flops();
            match op.class() {
                OpClass::Load => c.loads += 1,
                OpClass::Store => c.stores += 1,
                OpClass::Atomic => c.atomics += 1,
                OpClass::Control => c.branches += 1,
                OpClass::Compute | OpClass::Sync => {}
            }
            match *op {
                Op::Ld { addr, .. } | Op::St { addr, .. } | Op::AtomAdd { addr, .. } => {
                    c.reqs_per_class[numa.class_of(&map, tile, addr)] += 1;
                }
                Op::LdBurst { n, addr, .. } | Op::StBurst { n, addr, .. } => {
                    // Same run split as `route_action` → the burst/single
                    // request counts land bit-exact.
                    map.map_burst(addr, n, |bank, len| {
                        let cls = numa.class_of_bank(tile, bank);
                        c.reqs_per_class[cls] += 1;
                        if len > 1 {
                            c.burst_reqs_per_class[cls] += 1;
                            c.burst_words_per_class[cls] += len as u64;
                        }
                    });
                }
                Op::Barrier { .. } => {
                    c.barriers += 1;
                    let addr = map.seq_base_of_tile(tile) + BARRIER_SLOT;
                    c.reqs_per_class[numa.class_of(&map, tile, addr)] += 1;
                }
                Op::DmaStart { id } => {
                    c.dma_bytes += desc_bytes.get(&id).copied().unwrap_or(0);
                }
                _ => {}
            }
        }
    }

    // ---- layer 2: two-pass analytic schedule -----------------------
    let zero_load = [
        cfg.latency.local as f64,
        cfg.latency.subgroup as f64,
        cfg.latency.group as f64,
        cfg.latency.remote_group as f64,
    ];
    let tx_cap = cfg.tx_table_entries.max(1);

    // Pass 1 at zero-load latencies: a busy-cycle floor that turns the
    // census into per-class injection rates.
    let wakeup = cfg.barrier_wakeup as f64;
    let sched_all = |lat: &[f64; 4]| -> Vec<PeSched> {
        let run = |dma: &DmaTimeline| -> Vec<PeSched> {
            staged
                .programs
                .iter()
                .enumerate()
                .map(|(pe, p)| {
                    schedule_pe(p, pe / numa.pes_per_tile, &map, &numa, lat, tx_cap, dma)
                })
                .collect()
        };
        // Without HBML traffic one pass *is* the schedule. With it,
        // iterate the schedule ↔ timeline fixed point: waits lengthen
        // phases, which shifts later starts, which moves completions.
        // Two rounds settle the bulk-synchronous traces the
        // double-buffered kernels emit; a fixed count keeps the model
        // deterministic.
        let mut scheds = run(&DmaTimeline::default());
        if !desc_bytes.is_empty() {
            for _ in 0..2 {
                let dma = dma_timeline(cfg, &scheds, &desc_bytes, phase_starts(&scheds, wakeup));
                scheds = run(&dma);
            }
        }
        scheds
    };
    let pass1 = sched_all(&zero_load);
    let busy_mean = (pass1
        .iter()
        .map(|s| s.segments.iter().sum::<f64>())
        .sum::<f64>()
        / num_pes as f64)
        .max(1.0);

    // Closed-form contention at the census rates (Eqs. (4)–(6) through
    // `level_contention_at`), mapped back onto the engine's classes.
    let mut contention = [0.0f64; 4];
    let mut lat_eff = zero_load;
    for cls in 0..4 {
        let rate = (c.reqs_per_class[cls] as f64 / num_pes as f64 / busy_mean).min(1.0);
        contention[cls] = spec.level_contention_at(level_of_class(&spec, cls), rate);
        lat_eff[cls] += contention[cls];
    }

    // Pass 2 at effective latencies: the schedule the estimate reports.
    let pass2 = sched_all(&lat_eff);

    // Bulk-synchronous phase assembly: each phase costs its slowest PE,
    // the headroom of the others is their barrier synch stall, and each
    // release costs the configured wake-up broadcast latency.
    let n_phases = pass2.iter().map(|s| s.segments.len()).max().unwrap_or(1);
    let mut cycles = 0.0;
    let mut stall_synch = 0.0;
    for k in 0..n_phases {
        let seg = |s: &PeSched| s.segments.get(k).copied();
        let longest = pass2.iter().filter_map(seg).fold(0.0f64, f64::max);
        let barrier_phase = k + 1 < n_phases;
        for s in &pass2 {
            if let Some(mine) = seg(s) {
                if barrier_phase {
                    stall_synch += (longest - mine) + wakeup;
                }
            }
        }
        cycles += longest + if barrier_phase { wakeup + 1.0 } else { 0.0 };
    }
    let stall_raw: f64 = pass2.iter().map(|s| s.stall_raw).sum();
    let stall_lsu: f64 = pass2.iter().map(|s| s.stall_lsu).sum();
    stall_synch += pass2.iter().map(|s| s.dma_wait).sum::<f64>();

    // AMAT straight from the model: zero-load plus contention, weighted
    // by the exact class mix.
    let mut amat_per_class = [0.0f64; 4];
    let mut amat_num = 0.0;
    let total_reqs: u64 = c.reqs_per_class.iter().sum();
    for cls in 0..4 {
        if c.reqs_per_class[cls] > 0 {
            amat_per_class[cls] = zero_load[cls] + contention[cls];
            amat_num += amat_per_class[cls] * c.reqs_per_class[cls] as f64;
        }
    }
    let amat = if total_reqs == 0 { 0.0 } else { amat_num / total_reqs as f64 };

    ModelRun {
        census: c,
        cycles: cycles.max(1.0),
        stall_raw,
        stall_lsu,
        stall_ctrl: (c.branches * CTRL_BUBBLE as u64) as f64,
        stall_synch,
        amat,
        amat_per_class,
    }
}

/// Ratio calibration (module docs, layer 3): report
/// `actual × model_target / model_fast`, falling back to the raw model
/// when either anchor is degenerate (a field the calibration run never
/// exercised).
fn blend(actual: f64, model_target: f64, model_fast: f64) -> f64 {
    if model_fast > 0.0 {
        actual * model_target / model_fast
    } else if model_target > 0.0 {
        model_target
    } else {
        actual
    }
}

/// Assemble the estimated [`RunStats`] for the target-scale build
/// `target` from the calibration anchor (`fast_actual` measured on the
/// `fast_model` build). Census-backed fields are exact at the target
/// scale; timing fields are ratio-calibrated; `stall_ctrl` is exact by
/// construction. When the target build *is* the calibration build every
/// ratio is 1 and the estimate reproduces the measurement.
pub fn calibrated_stats(
    cfg: &ClusterConfig,
    target: &ModelRun,
    fast_actual: &RunStats,
    fast_model: &ModelRun,
) -> RunStats {
    let c = &target.census;
    let cycles = blend(fast_actual.cycles as f64, target.cycles, fast_model.cycles)
        .round()
        .max(1.0) as u64;
    let mut amat_per_class = [0.0f64; 4];
    for cls in 0..4 {
        if c.reqs_per_class[cls] > 0 {
            amat_per_class[cls] = blend(
                fast_actual.amat_per_class[cls],
                target.amat_per_class[cls],
                fast_model.amat_per_class[cls],
            );
        }
    }
    RunStats {
        cycles,
        instructions: c.instructions,
        flops: c.flops,
        num_pes: cfg.num_pes(),
        freq_mhz: cfg.freq_mhz,
        stall_raw: blend(fast_actual.stall_raw as f64, target.stall_raw, fast_model.stall_raw)
            .round() as u64,
        stall_lsu: blend(fast_actual.stall_lsu as f64, target.stall_lsu, fast_model.stall_lsu)
            .round() as u64,
        stall_ctrl: c.branches * CTRL_BUBBLE as u64,
        stall_synch: blend(
            fast_actual.stall_synch as f64,
            target.stall_synch,
            fast_model.stall_synch,
        )
        .round() as u64,
        loads: c.loads,
        stores: c.stores,
        atomics: c.atomics,
        amat: blend(fast_actual.amat, target.amat, fast_model.amat),
        amat_per_class,
        reqs_per_class: c.reqs_per_class,
        burst_reqs_per_class: c.burst_reqs_per_class,
        burst_words_per_class: c.burst_words_per_class,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, Scale};
    use crate::kernels::axpy::{Axpy, AxpyParams};
    use crate::kernels::Workload;

    /// The census half must reproduce the engine's exact counters —
    /// that is what makes the EXACT fields of `report_diff` hold at
    /// zero drift between estimate and measurement.
    #[test]
    fn census_matches_engine_exact_counts() {
        let cfg = ClusterConfig::tiny();
        for w in ["axpy", "dotp", "gemm"] {
            let w = crate::kernels::lookup(w).unwrap();
            let staged = w.build(&cfg, Scale::Fast);
            let m = model_run(&cfg, &staged);
            let (mut cl, io) = staged.into_cluster(cfg.clone());
            let stats = cl.try_run(50_000_000).unwrap();
            assert_eq!(m.census.instructions, stats.instructions, "{}", io.name);
            assert_eq!(m.census.flops, stats.flops, "{}", io.name);
            assert_eq!(m.census.loads, stats.loads, "{}", io.name);
            assert_eq!(m.census.stores, stats.stores, "{}", io.name);
            assert_eq!(m.census.atomics, stats.atomics, "{}", io.name);
            assert_eq!(m.census.reqs_per_class, stats.reqs_per_class, "{}", io.name);
            assert_eq!(
                m.stall_ctrl as u64, stats.stall_ctrl,
                "{}: branch bubbles are exact",
                io.name
            );
        }
    }

    /// Burst mode: the census's `map_burst` split must land on the same
    /// request totals *and* the same burst/single division the engine's
    /// `ClassStats` measures — for all three burst-emitting kernels.
    #[test]
    fn census_matches_engine_burst_counts() {
        let cfg = ClusterConfig::tiny().with_burst(true);
        let nb = cfg.num_banks();
        let workloads: Vec<Box<dyn Workload>> = vec![
            Box::new(Axpy::with(AxpyParams { n: nb * 4, alpha: 2.0 })),
            Box::new(crate::kernels::dotp::Dotp::with(crate::kernels::dotp::DotpParams {
                n: nb * 4,
            })),
            Box::new(crate::kernels::spmmadd::Spmmadd::with(
                crate::kernels::spmmadd::SpmmaddParams {
                    rows: 128,
                    cols: 128,
                    nnz_per_row: 4,
                    seed: 7,
                },
            )),
        ];
        for w in &workloads {
            let staged = w.build(&cfg, Scale::Fast);
            let m = model_run(&cfg, &staged);
            let (mut cl, io) = staged.into_cluster(cfg.clone());
            let stats = cl.try_run(50_000_000).unwrap();
            assert_eq!(m.census.reqs_per_class, stats.reqs_per_class, "{}", io.name);
            assert_eq!(
                m.census.burst_reqs_per_class, stats.burst_reqs_per_class,
                "{}",
                io.name
            );
            assert_eq!(
                m.census.burst_words_per_class, stats.burst_words_per_class,
                "{}",
                io.name
            );
            assert!(
                m.census.burst_reqs_per_class.iter().sum::<u64>() > 0,
                "{}: expected burst traffic",
                io.name
            );
        }
    }

    /// Calibrating against the very build being estimated collapses
    /// every ratio to 1: the estimate must reproduce the measurement.
    #[test]
    fn estimate_is_exact_at_calibration_scale() {
        let cfg = ClusterConfig::tiny();
        let w = Axpy::default();
        let staged = w.build(&cfg, Scale::Fast);
        let m = model_run(&cfg, &staged);
        let (mut cl, _) = staged.into_cluster(cfg.clone());
        let actual = cl.try_run(50_000_000).unwrap();
        let est = calibrated_stats(&cfg, &m, &actual, &m);
        assert_eq!(est, actual);
    }

    /// The headline accuracy property: calibrate on a small instance,
    /// extrapolate 8× — the prediction must track the engine within the
    /// stated bound on an off-saturation (local-traffic) config.
    #[test]
    fn extrapolated_cycles_within_bound() {
        let cfg = ClusterConfig::tiny();
        let nb = cfg.num_banks();
        let small = Axpy::with(AxpyParams { n: nb * 4, alpha: 2.0 });
        let big = Axpy::with(AxpyParams { n: nb * 32, alpha: 2.0 });

        let staged_small = small.build(&cfg, Scale::Fast);
        let m_small = model_run(&cfg, &staged_small);
        let (mut cl, _) = staged_small.into_cluster(cfg.clone());
        let actual_small = cl.try_run(50_000_000).unwrap();

        let staged_big = big.build(&cfg, Scale::Fast);
        let m_big = model_run(&cfg, &staged_big);
        let est = calibrated_stats(&cfg, &m_big, &actual_small, &m_small);

        let (mut cl, _) = staged_big.into_cluster(cfg.clone());
        let actual_big = cl.try_run(50_000_000).unwrap();

        let rel = |e: u64, a: u64| (e as f64 - a as f64).abs() / a as f64;
        assert!(
            rel(est.cycles, actual_big.cycles) < 0.10,
            "cycles: est {} vs actual {}",
            est.cycles,
            actual_big.cycles
        );
        // Exact fields carry zero drift by construction.
        assert_eq!(est.instructions, actual_big.instructions);
        assert_eq!(est.reqs_per_class, actual_big.reqs_per_class);
    }

    /// The fluid engine model in isolation: back-to-back starts queue
    /// behind the one CSR frontend slot, and a transfer sharing the
    /// channels with a concurrent sibling finishes later than the same
    /// transfer running alone.
    #[test]
    fn dma_timeline_serializes_frontend_and_shares_bandwidth() {
        let cfg = ClusterConfig::tiny();
        let bytes = HashMap::from([(0u16, 1u64 << 20), (1u16, 1u64 << 20)]);
        let scheds = vec![PeSched {
            segments: vec![1000.0],
            dma_starts: vec![(0, 0, 10.0), (1, 0, 10.0)],
            ..PeSched::default()
        }];
        let tl = dma_timeline(&cfg, &scheds, &bytes, phase_starts(&scheds, 0.0));
        let shared = tl.done[&0];
        assert!(tl.done[&1] > shared, "second start queues behind the frontend");

        let solo_bytes = HashMap::from([(0u16, 1u64 << 20)]);
        let scheds = vec![PeSched {
            segments: vec![1000.0],
            dma_starts: vec![(0, 0, 10.0)],
            ..PeSched::default()
        }];
        let tl = dma_timeline(&cfg, &scheds, &solo_bytes, phase_starts(&scheds, 0.0));
        assert!(tl.done[&0] < shared, "a concurrent sibling must slow the transfer");
    }

    /// The widened DMA model must preserve the blend collapse: a
    /// double-buffered (HBML-streaming) build calibrated against itself
    /// reproduces the measurement bit-exactly, DmaWait parks and all.
    #[test]
    fn db_estimate_exact_at_calibration_scale() {
        let cfg = ClusterConfig::tiny();
        let w = crate::kernels::lookup("db-axpy").unwrap();
        let staged = w.build(&cfg, Scale::Fast);
        let m = model_run(&cfg, &staged);
        assert!(m.census.dma_bytes > 0, "db kernels must stream HBML bytes");
        let (mut cl, _) = staged.into_cluster(cfg.clone());
        let actual = cl.try_run(50_000_000).unwrap();
        let est = calibrated_stats(&cfg, &m, &actual, &m);
        assert_eq!(est, actual);
    }

    /// Extrapolating a double-buffered kernel from the Fast build to
    /// the Full build (2× chunk, 2× rounds) must stay within the stated
    /// bound: the fluid DMA model has to keep the compute/transfer
    /// overlap regime consistent across scales for the ratio
    /// calibration to cancel its bias.
    #[test]
    fn db_extrapolation_tracks_engine() {
        let cfg = ClusterConfig::tiny();
        let w = crate::kernels::lookup("db-axpy").unwrap();

        let staged_small = w.build(&cfg, Scale::Fast);
        let m_small = model_run(&cfg, &staged_small);
        let (mut cl, _) = staged_small.into_cluster(cfg.clone());
        let actual_small = cl.try_run(50_000_000).unwrap();

        let staged_big = w.build(&cfg, Scale::Full);
        let m_big = model_run(&cfg, &staged_big);
        let est = calibrated_stats(&cfg, &m_big, &actual_small, &m_small);

        let (mut cl, _) = staged_big.into_cluster(cfg.clone());
        let actual_big = cl.try_run(50_000_000).unwrap();

        let rel = (est.cycles as f64 - actual_big.cycles as f64).abs() / actual_big.cycles as f64;
        assert!(
            rel < 0.10,
            "db-axpy cycles: est {} vs actual {} (rel {rel:.3})",
            est.cycles,
            actual_big.cycles
        );
        assert_eq!(est.instructions, actual_big.instructions);
        assert_eq!(est.reqs_per_class, actual_big.reqs_per_class);
    }

    #[test]
    fn class_level_mapping_collapses_with_hierarchy() {
        // tiny is 4C-2T-2SG-2G: four live levels, identity mapping.
        let spec = hier_of(&ClusterConfig::tiny());
        assert_eq!(spec.levels(), 4);
        for cls in 0..4 {
            assert_eq!(level_of_class(&spec, cls), cls);
        }
        // mempool is 4C-16T-1SG-4G: three levels; the engine's
        // RemoteGroup class contends at HierSpec level 2.
        let spec = hier_of(&ClusterConfig::mempool());
        assert_eq!(spec.levels(), 3);
        assert_eq!(level_of_class(&spec, 3), 2);
        assert_eq!(level_of_class(&spec, 1), 1);
    }
}
