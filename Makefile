# Build-time entry points. The Rust crate is self-contained; Python (JAX)
# runs only for `make artifacts`.

.PHONY: artifacts build test bench bench-check report-diff pytest

# AOT-lower the JAX entries and evaluate the golden outputs into
# artifacts/ (needs jax + numpy; see python/compile/aot.py).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

# Tier-1 verify.
build:
	cargo build --release

test: build
	cargo test -q

bench:
	cargo bench --bench simspeed
	cargo bench --bench scaling

# Regenerate BENCH_simspeed.json and gate it against the committed
# baseline (>25% sim-speed regression on any row fails; see
# tools/bench_gate.py — advisory in CI, blocking here).
bench-check:
	cargo bench --bench simspeed
	python3 tools/bench_gate.py

# Field-by-field diff of two RunReport documents (terapool-runreport-v1)
# with tolerances — paper-vs-measured drift tracking. Usage:
#   make report-diff OLD=baseline.json NEW=report.json [RTOL=0.02]
report-diff:
	python3 tools/report_diff.py $(OLD) $(NEW) --rtol $(or $(RTOL),0.0)

pytest:
	python3 -m pytest python/tests -q
