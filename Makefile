# Build-time entry points. The Rust crate is self-contained; Python (JAX)
# runs only for `make artifacts`.

.PHONY: artifacts build test bench pytest

# AOT-lower the JAX entries and evaluate the golden outputs into
# artifacts/ (needs jax + numpy; see python/compile/aot.py).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

# Tier-1 verify.
build:
	cargo build --release

test: build
	cargo test -q

bench:
	cargo bench --bench simspeed
	cargo bench --bench scaling

pytest:
	python3 -m pytest python/tests -q
